//! The four-term parametric plasticity rule (§II-A) — the paper's core
//! algorithmic contribution:
//!
//! ```text
//! Δw_ij = α_ij·S_j·S_i  +  β_ij·S_j  +  γ_ij·S_i  +  δ_ij
//!         └─ Associative ┘  └ Presyn ┘  └ Postsyn ┘  └ Decay ┘
//! ```
//!
//! θ = {α, β, γ, δ} is learned **offline** by the evolution strategy
//! (Phase 1) and then frozen; **online** (Phase 2) the rule continuously
//! updates the synaptic weights starting from zero.
//!
//! Storage layout matches the hardware: the four coefficient planes are
//! *packed per synapse* (`[α,β,γ,δ]` contiguous) so one wide memory read
//! feeds all four multipliers of the Plasticity Engine — and, in the
//! Pallas kernel, one VMEM tile fetch covers all four terms (see
//! DESIGN.md §Hardware-Adaptation).

use super::numeric::Scalar;
use super::spike::{words_for, LANES};
use crate::util::rng::Pcg64;

/// Per-synapse packed rule coefficients for one layer: `pre × post`
/// synapses, 4 coefficients each, row-major `[pre][post][4]`.
#[derive(Clone, Debug)]
pub struct RuleParams {
    /// Presynaptic population size.
    pub pre: usize,
    /// Postsynaptic population size.
    pub post: usize,
    /// Packed [α, β, γ, δ] × (pre·post), f32 master copy (ES space).
    pub theta: Vec<f32>,
}

/// Coefficients stored per synapse (α, β, γ, δ).
pub const COEFFS_PER_SYNAPSE: usize = 4;

impl RuleParams {
    /// All-zero rule for a `pre × post` synaptic layer.
    pub fn zeros(pre: usize, post: usize) -> Self {
        RuleParams {
            pre,
            post,
            theta: vec![0.0; pre * post * COEFFS_PER_SYNAPSE],
        }
    }

    /// Random initialization for ES seeding: small centered Gaussians.
    pub fn random(pre: usize, post: usize, sigma: f32, rng: &mut Pcg64) -> Self {
        let mut p = Self::zeros(pre, post);
        rng.fill_normal_f32(&mut p.theta, sigma);
        p
    }

    /// Number of f32 parameters in this layer's rule (4 per synapse).
    pub fn n_params(&self) -> usize {
        self.theta.len()
    }

    /// Offset of synapse (j → i)'s packed quadruple inside `theta`.
    #[inline]
    pub fn idx(&self, j_pre: usize, i_post: usize) -> usize {
        (j_pre * self.post + i_post) * COEFFS_PER_SYNAPSE
    }

    /// The packed quadruple for synapse (j → i).
    #[inline]
    pub fn coeffs(&self, j_pre: usize, i_post: usize) -> [f32; 4] {
        let k = self.idx(j_pre, i_post);
        [
            self.theta[k],
            self.theta[k + 1],
            self.theta[k + 2],
            self.theta[k + 3],
        ]
    }

    /// Copy from a flat ES genome segment.
    pub fn load_flat(&mut self, flat: &[f32]) {
        assert_eq!(flat.len(), self.theta.len());
        self.theta.copy_from_slice(flat);
    }

    /// Split coefficient planes: returns (α, β, γ, δ) as `pre×post`
    /// row-major matrices — the layout the XLA artifact consumes
    /// (stacked `[4, pre, post]`).
    pub fn unpack_planes(&self) -> [Vec<f32>; 4] {
        let n = self.pre * self.post;
        let mut planes = [vec![0.0; n], vec![0.0; n], vec![0.0; n], vec![0.0; n]];
        for s in 0..n {
            for c in 0..4 {
                planes[c][s] = self.theta[s * 4 + c];
            }
        }
        planes
    }

    /// Inverse of [`unpack_planes`].
    pub fn from_planes(pre: usize, post: usize, planes: &[Vec<f32>; 4]) -> Self {
        let n = pre * post;
        let mut p = Self::zeros(pre, post);
        for s in 0..n {
            for c in 0..4 {
                p.theta[s * 4 + c] = planes[c][s];
            }
        }
        p
    }
}

/// Hyper-parameters of the online update.
#[derive(Clone, Copy, Debug)]
pub struct PlasticityConfig {
    /// Global learning-rate scale η applied to Δw (the paper folds this
    /// into θ; keeping it explicit lets the ES search a normalized space).
    pub eta: f32,
    /// Symmetric weight clip: w ∈ [−w_clip, +w_clip]. Bounded weights are
    /// what δ's "synaptic regularization" stabilizes; the clip is the
    /// hardware's saturation backstop.
    pub w_clip: f32,
    /// Event-driven presynaptic gating (DESIGN.md §Hot-Path): when set,
    /// [`apply_update_batch`] skips every presynaptic row whose trace is
    /// below [`PlasticityConfig::trace_eps`] in all active sessions, so
    /// plasticity cost tracks trace sparsity the way the packed matvec
    /// already tracks firing rate — the software rendition of the
    /// Plasticity Engine's spike-event gating.
    ///
    /// **Tolerance contract** (the reason this is opt-in, default
    /// `false`): a skipped row omits its synapses' presyn-independent
    /// terms `γ·Sᵢ + δ` for that tick. With the FP16-aware default
    /// `trace_eps = 2⁻²⁴` (the smallest positive FP16 subnormal) a
    /// sub-ε pre-trace is *exactly zero* in the FP16 domain — there the
    /// gate drops only terms a rule with `γ = δ = 0` never produces, and
    /// gated FP16 runs with such rules are bit-identical to ungated
    /// ones. For general rules the per-tick weight deviation of a
    /// skipped synapse is bounded by `η·(|γ|·Sᵢ + |δ| + ε·(|α|·Sᵢ + |β|))`.
    /// Gated runs are compared bit-exactly against the **identically
    /// gated** dense oracle
    /// ([`crate::snn::reference::apply_update_batch_dense`]); the
    /// gated-vs-ungated deviation is the documented ε-tolerance.
    pub presyn_gate: bool,
    /// Zero threshold of the presynaptic gate. Default `2⁻²⁴` — the
    /// smallest positive FP16 subnormal, so f32 and FP16 deployments
    /// gate consistently ("FP16-aware"). Traces are non-negative; a row
    /// is skipped iff every active lane's pre-trace is `< trace_eps`.
    /// Setting `0.0` makes the gate a no-op (nothing is below zero).
    ///
    /// **Coarse-domain extension (Qfx):** the threshold is quantized into
    /// the scalar domain with *ceiling* rounding
    /// ([`crate::snn::numeric::Scalar::quantize_threshold`]), never
    /// to-nearest. In f32/F16 the default ε is exactly representable and
    /// nothing changes; in Q5.10 fixed point ε floors at one quantum
    /// (2⁻¹⁰), so a skipped row is one whose pre-traces are all *exactly
    /// zero* — the same rows the lazy hot-mask prefilter skips, and the
    /// same lossless γ = δ = 0 guarantee the FP16 sub-ε case gives:
    /// sub-quantum traces don't exist in Qfx, a decayed trace is exactly
    /// zero, so the gate drops only terms such a rule never produces.
    pub trace_eps: f32,
}

impl Default for PlasticityConfig {
    fn default() -> Self {
        PlasticityConfig {
            eta: 0.05,
            w_clip: 4.0,
            presyn_gate: false,
            trace_eps: 1.0 / 16_777_216.0, // 2^-24, FP16 min subnormal
        }
    }
}

/// Apply one plasticity step to a layer's weight matrix.
///
/// `weights` is `pre × post` row-major. `pre_trace`/`post_trace` are the
/// spike traces *after* this timestep's trace update — the paper computes
/// the synaptic update "based on the spike traces from the current
/// timestep" (§III-C Phase A).
///
/// Generic over the scalar domain so the identical code path serves the
/// f32 golden model and the FP16 FPGA-equivalent model.
pub fn apply_update<S: Scalar>(
    params: &RuleParams,
    cfg: &PlasticityConfig,
    weights: &mut [S],
    pre_trace: &[S],
    post_trace: &[S],
) {
    assert_eq!(weights.len(), params.pre * params.post);
    assert_eq!(pre_trace.len(), params.pre);
    assert_eq!(post_trace.len(), params.post);
    let eta = S::from_f32(cfg.eta);
    let lo = S::from_f32(-cfg.w_clip);
    let hi = S::from_f32(cfg.w_clip);

    for j in 0..params.pre {
        let sj = pre_trace[j];
        let row = j * params.post;
        // chunks_exact keeps the four-coefficient fetch a single
        // bounds-checked slice per synapse (SIMD-readiness contract,
        // DESIGN.md §Hot-Path).
        let t_lo = row * COEFFS_PER_SYNAPSE;
        let t_hi = (row + params.post) * COEFFS_PER_SYNAPSE;
        let theta_row = params.theta[t_lo..t_hi].chunks_exact(COEFFS_PER_SYNAPSE);
        for ((w, si), q) in weights[row..row + params.post]
            .iter_mut()
            .zip(post_trace)
            .zip(theta_row)
        {
            let coeffs = [
                S::from_f32(q[0]),
                S::from_f32(q[1]),
                S::from_f32(q[2]),
                S::from_f32(q[3]),
            ];
            *w = update_synapse(coeffs, eta, lo, hi, *w, sj, *si);
        }
    }
}

/// Batched plasticity step over `batch` independent sessions sharing one
/// frozen rule θ (the memory-layout point of DESIGN.md §Batched-Serving:
/// θ is 4× the size of a weight matrix, and batching turns its per-step
/// streaming cost from `O(batch)` into `O(1)`).
///
/// Layouts are structure-of-arrays: `weights` is
/// `pre × post × batch` (`[synapse][session]`), traces are
/// `neurons × batch` (`[neuron][session]`). The session mask arrives
/// bit-packed (`active_words`, one bit per session lane — see
/// [`crate::snn::spike::pack_mask_into`]). Full-batch ticks take a
/// mask-free contiguous sweep; partially-active ticks walk only the set
/// mask bits, so masked-off sessions cost nothing and keep their
/// weights untouched. The
/// per-synapse datapath is [`update_synapse`] — the same function the
/// single-session [`apply_update`] uses — with identical operation
/// order, so a batched session is bit-equivalent to a lone network fed
/// the same history.
///
/// With [`PlasticityConfig::presyn_gate`] set, presynaptic rows whose
/// trace is below [`PlasticityConfig::trace_eps`] in every active lane
/// are **skipped entirely** (the event-driven path; see the field docs
/// for the tolerance contract), so the sweep cost scales with the
/// active-presynaptic set instead of `pre × post × batch`.
///
/// `hot` is an optional **row prefilter**: the lazy input traces'
/// per-`(neuron, word)` hot-lane masks
/// ([`crate::snn::TraceVector::hot_rows`], `pre × words_for(batch)`
/// words). A row whose masks satisfy `hot & active == 0` in every word
/// has *exactly zero* trace in every active lane (the lazy-trace cold
/// invariant), so with `trace_eps > 0` the gate skips it in **one AND
/// per word** instead of an O(batch) value scan. Rows that fail the
/// prefilter still take the value scan, so the gate's skip decisions —
/// and the returned visited count — are bit-identical to a value-scan-
/// only sweep (and to the dense oracle's). Pass `None` when no hot
/// bookkeeping exists (eager traces, or the gate is off).
///
/// Returns the number of presynaptic rows visited (== `params.pre`
/// when the gate is off).
#[allow(clippy::too_many_arguments)]
pub fn apply_update_batch<S: Scalar>(
    params: &RuleParams,
    cfg: &PlasticityConfig,
    batch: usize,
    active_words: &[u64],
    hot: Option<&[u64]>,
    weights: &mut [S],
    pre_trace: &[S],
    post_trace: &[S],
) -> usize {
    assert_eq!(weights.len(), params.pre * params.post * batch);
    assert_eq!(pre_trace.len(), params.pre * batch);
    assert_eq!(post_trace.len(), params.post * batch);
    assert_eq!(active_words.len(), words_for(batch), "mask/batch mismatch");
    let wpr = active_words.len();
    if let Some(h) = hot {
        assert_eq!(h.len(), params.pre * wpr, "hot/rows mismatch");
    }
    // The prefilter's soundness needs ε > 0: a cold lane is exactly zero,
    // and only then is "zero" guaranteed below the gate threshold. At
    // ε = 0 the gate is a documented no-op, so the prefilter must be too.
    let prefilter = cfg.presyn_gate && cfg.trace_eps > 0.0;
    let eta = S::from_f32(cfg.eta);
    let lo = S::from_f32(-cfg.w_clip);
    let hi = S::from_f32(cfg.w_clip);
    // Ceiling ε quantization (identical in the dense oracle): a positive
    // threshold never rounds down to zero in a coarse domain, so the
    // value scan below and the hot-mask prefilter above agree on which
    // rows carry no representable drive.
    let eps = S::quantize_threshold(cfg.trace_eps);
    // Full-batch ticks (the serving steady state) take a mask-free inner
    // loop: a branchless contiguous sweep over the session lanes that
    // the compiler can keep in SIMD registers.
    let all_active = active_words.iter().enumerate().all(|(wi, &aw)| {
        let lanes = (batch - wi * LANES).min(LANES);
        let full = if lanes == LANES { u64::MAX } else { (1u64 << lanes) - 1 };
        aw == full
    });

    let mut visited = 0usize;
    for j in 0..params.pre {
        let pre_row = &pre_trace[j * batch..(j + 1) * batch];
        // Hot-mask prefilter (ROADMAP follow-up, landed): every active
        // lane cold ⇒ exactly zero ⇒ sub-ε — skip without touching the
        // trace values at all.
        if prefilter {
            if let Some(h) = hot {
                let hrow = &h[j * wpr..(j + 1) * wpr];
                if hrow.iter().zip(active_words).all(|(&hw, &aw)| hw & aw == 0) {
                    continue;
                }
            }
        }
        // Event-driven skip: a row whose pre-trace is sub-ε in every
        // active lane contributes no representable presynaptic drive —
        // one O(batch) scan replaces an O(post × batch) update sweep.
        if cfg.presyn_gate && row_below_eps(pre_row, active_words, eps) {
            continue;
        }
        visited += 1;
        let row = j * params.post;
        // One θ fetch serves every session of a synapse; chunks_exact
        // keeps it a single bounds-checked slice per synapse.
        let t_lo = row * COEFFS_PER_SYNAPSE;
        let t_hi = (row + params.post) * COEFFS_PER_SYNAPSE;
        let theta_row = params.theta[t_lo..t_hi].chunks_exact(COEFFS_PER_SYNAPSE);
        for (i, q) in theta_row.enumerate() {
            let coeffs = [
                S::from_f32(q[0]),
                S::from_f32(q[1]),
                S::from_f32(q[2]),
                S::from_f32(q[3]),
            ];
            let post_row = &post_trace[i * batch..(i + 1) * batch];
            let wbase = (row + i) * batch;
            let wrow = &mut weights[wbase..wbase + batch];
            if all_active {
                // Contiguous lane zip: the auto-vectorization shape
                // (slice iterators, no indexing) — DESIGN.md §Hot-Path.
                for ((w, &pj), &pi) in wrow.iter_mut().zip(pre_row).zip(post_row) {
                    *w = update_synapse(coeffs, eta, lo, hi, *w, pj, pi);
                }
            } else {
                // Partially-active tick: walk only the set mask bits, so
                // the per-synapse cost scales with the number of active
                // sessions, not the provisioned batch.
                for (wi, &aw) in active_words.iter().enumerate() {
                    let mut m = aw;
                    while m != 0 {
                        let b = wi * LANES + m.trailing_zeros() as usize;
                        m &= m - 1;
                        wrow[b] =
                            update_synapse(coeffs, eta, lo, hi, wrow[b], pre_row[b], post_row[b]);
                    }
                }
            }
        }
    }
    visited
}

/// Gate predicate of the event-driven plasticity sweep: true iff every
/// active lane's pre-trace is below `eps`. Traces are non-negative, so
/// "below ε" and "no representable drive at ε-granularity" coincide;
/// with the FP16-aware default ε = 2⁻²⁴ an FP16 sub-ε trace is exactly
/// zero. Shared (by construction, not by call) with the dense oracle's
/// gate in [`crate::snn::reference::apply_update_batch_dense`], which
/// must make identical decisions for the equivalence suite to pin gated
/// runs bit-exactly.
#[inline]
pub fn row_below_eps<S: Scalar>(pre_row: &[S], active_words: &[u64], eps: S) -> bool {
    for (wi, &aw) in active_words.iter().enumerate() {
        for l in crate::snn::spike::set_bits(aw) {
            if pre_row[wi * LANES + l] >= eps {
                return false;
            }
        }
    }
    true
}

/// One synapse's update — the exact datapath of the Plasticity Engine
/// (four parallel products + pipelined adder tree + scaled saturating
/// accumulate). Shared by the golden model and the FPGA simulator so
/// both are bit-identical by construction:
/// `w' = clamp(w ⊕ η·((α·Sj·Si + β·Sj) + (γ·Si + δ)))`.
#[inline]
pub fn update_synapse<S: Scalar>(
    coeffs: [S; 4],
    eta: S,
    lo: S,
    hi: S,
    w: S,
    sj: S,
    si: S,
) -> S {
    let [alpha, beta, gamma, delta] = coeffs;
    let assoc = alpha.mul(sj).mul(si);
    let presyn = beta.mul(sj);
    let postsyn = gamma.mul(si);
    let t0 = assoc.add(presyn);
    let t1 = postsyn.add(delta);
    let dw = t0.add(t1);
    w.saturating_add(eta.mul(dw)).clamp(lo, hi)
}

/// Reference Δw for a single synapse in f64 (oracle for tests).
pub fn delta_w_reference(coeffs: [f32; 4], sj: f32, si: f32) -> f64 {
    let [a, b, g, d] = coeffs;
    a as f64 * sj as f64 * si as f64 + b as f64 * sj as f64 + g as f64 * si as f64 + d as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fp16::F16;

    fn simple_params() -> RuleParams {
        let mut p = RuleParams::zeros(2, 3);
        // synapse (0,0): pure Hebbian α=1
        let k00 = p.idx(0, 0);
        p.theta[k00] = 1.0;
        // synapse (1,2): pure decay δ=−1
        let k = p.idx(1, 2);
        p.theta[k + 3] = -1.0;
        p
    }

    #[test]
    fn hebbian_term_strengthens_correlated() {
        let p = simple_params();
        let cfg = PlasticityConfig {
            eta: 1.0,
            w_clip: 10.0,
            ..PlasticityConfig::default()
        };
        let mut w = vec![0.0f32; 6];
        let pre = vec![1.0f32, 0.0];
        let post = vec![1.0f32, 0.0, 0.0];
        apply_update(&p, &cfg, &mut w, &pre, &post);
        assert_eq!(w[0], 1.0); // α·1·1
        assert_eq!(w[1], 0.0);
    }

    #[test]
    fn decay_term_reduces_weight_unconditionally() {
        let p = simple_params();
        let cfg = PlasticityConfig {
            eta: 0.5,
            w_clip: 10.0,
            ..PlasticityConfig::default()
        };
        let mut w = vec![0.0f32; 6];
        let pre = vec![0.0f32; 2];
        let post = vec![0.0f32; 3];
        apply_update(&p, &cfg, &mut w, &pre, &post);
        // synapse (1,2) is index 1*3+2 = 5
        assert_eq!(w[5], -0.5);
    }

    #[test]
    fn clip_bounds_weights() {
        let mut p = RuleParams::zeros(1, 1);
        p.theta[1] = 100.0; // huge β
        let cfg = PlasticityConfig {
            eta: 1.0,
            w_clip: 2.0,
            ..PlasticityConfig::default()
        };
        let mut w = vec![0.0f32];
        apply_update(&p, &cfg, &mut w, &[1.0], &[0.0]);
        assert_eq!(w[0], 2.0);
    }

    #[test]
    fn matches_reference_formula() {
        let mut rng = Pcg64::new(1, 0);
        let p = RuleParams::random(4, 5, 0.5, &mut rng);
        let cfg = PlasticityConfig {
            eta: 1.0,
            w_clip: 1e9,
            ..PlasticityConfig::default()
        };
        let mut w = vec![0.0f32; 20];
        let pre: Vec<f32> = (0..4).map(|j| 0.25 * j as f32).collect();
        let post: Vec<f32> = (0..5).map(|i| 0.5 * i as f32).collect();
        apply_update(&p, &cfg, &mut w, &pre, &post);
        for j in 0..4 {
            for i in 0..5 {
                let expect = delta_w_reference(p.coeffs(j, i), pre[j], post[i]);
                let got = w[j * 5 + i] as f64;
                assert!((got - expect).abs() < 1e-5, "({j},{i}): {got} vs {expect}");
            }
        }
    }

    #[test]
    fn f16_update_close_to_f32() {
        let mut rng = Pcg64::new(2, 0);
        let p = RuleParams::random(8, 8, 0.3, &mut rng);
        let cfg = PlasticityConfig::default();
        let mut wf = vec![0.0f32; 64];
        let mut wh = vec![F16::ZERO; 64];
        let pre_f: Vec<f32> = (0..8).map(|j| (j as f32 * 0.3) % 2.0).collect();
        let post_f: Vec<f32> = (0..8).map(|i| (i as f32 * 0.7) % 2.0).collect();
        let pre_h: Vec<F16> = pre_f.iter().map(|&x| F16::from_f32(x)).collect();
        let post_h: Vec<F16> = post_f.iter().map(|&x| F16::from_f32(x)).collect();
        for _ in 0..50 {
            apply_update(&p, &cfg, &mut wf, &pre_f, &post_f);
            apply_update(&p, &cfg, &mut wh, &pre_h, &post_h);
        }
        for k in 0..64 {
            let err = (wf[k] - wh[k].to_f32()).abs();
            assert!(err < 0.05, "synapse {k}: f32 {} vs f16 {}", wf[k], wh[k]);
        }
    }

    #[test]
    fn planes_round_trip() {
        let mut rng = Pcg64::new(3, 0);
        let p = RuleParams::random(3, 7, 1.0, &mut rng);
        let planes = p.unpack_planes();
        let q = RuleParams::from_planes(3, 7, &planes);
        assert_eq!(p.theta, q.theta);
    }

    #[test]
    fn batched_update_matches_sequential_singles() {
        let mut rng = Pcg64::new(11, 0);
        let p = RuleParams::random(5, 4, 0.4, &mut rng);
        let cfg = PlasticityConfig::default();
        let batch = 3;

        // independent per-session traces
        let mut pre_b = vec![0.0f32; 5 * batch];
        let mut post_b = vec![0.0f32; 4 * batch];
        rng.fill_normal_f32(&mut pre_b, 0.8);
        rng.fill_normal_f32(&mut post_b, 0.8);

        let mut w_b = vec![0.0f32; 5 * 4 * batch];
        let mask = crate::snn::spike::mask_words(&[true, true, false]);
        for _ in 0..20 {
            apply_update_batch(&p, &cfg, batch, &mask, None, &mut w_b, &pre_b, &post_b);
        }

        for b in 0..batch {
            let pre: Vec<f32> = (0..5).map(|j| pre_b[j * batch + b]).collect();
            let post: Vec<f32> = (0..4).map(|i| post_b[i * batch + b]).collect();
            let mut w = vec![0.0f32; 20];
            let steps = if b == 2 { 0 } else { 20 }; // session 2 was masked off
            for _ in 0..steps {
                apply_update(&p, &cfg, &mut w, &pre, &post);
            }
            for s in 0..20 {
                assert_eq!(w_b[s * batch + b], w[s], "session {b} synapse {s}");
            }
        }
    }

    #[test]
    fn gate_skips_silent_presynaptic_rows() {
        // ISSUE 3 acceptance: at 5 % (spatial) presynaptic activity the
        // gated sweep must touch < 20 % of the pre rows, and visited
        // rows must be updated identically to the ungated sweep.
        let pre = 100;
        let post = 16;
        let batch = 3;
        let mut rng = Pcg64::new(70, 0);
        let p = RuleParams::random(pre, post, 0.3, &mut rng);
        let cfg_gated = PlasticityConfig {
            presyn_gate: true,
            ..PlasticityConfig::default()
        };
        let cfg_plain = PlasticityConfig::default();

        // 5 % of rows carry trace mass; the rest are exactly silent.
        let mut pre_trace = vec![0.0f32; pre * batch];
        let live: Vec<usize> = (0..pre).filter(|j| j % 20 == 0).collect();
        for &j in &live {
            for b in 0..batch {
                pre_trace[j * batch + b] = 0.5 + 0.1 * b as f32;
            }
        }
        let mut post_trace = vec![0.0f32; post * batch];
        rng.fill_normal_f32(&mut post_trace, 0.5);
        for t in post_trace.iter_mut() {
            *t = t.abs();
        }

        let mask = crate::snn::spike::full_mask(batch);
        let mut w_gated = vec![0.0f32; pre * post * batch];
        let visited = apply_update_batch(
            &p, &cfg_gated, batch, &mask, None, &mut w_gated, &pre_trace, &post_trace,
        );
        assert_eq!(visited, live.len(), "gate must visit exactly the live rows");
        assert!(
            (visited as f64) < 0.2 * pre as f64,
            "visited {visited} of {pre} rows at 5 % activity"
        );

        let mut w_plain = vec![0.0f32; pre * post * batch];
        let visited_plain = apply_update_batch(
            &p, &cfg_plain, batch, &mask, None, &mut w_plain, &pre_trace, &post_trace,
        );
        assert_eq!(visited_plain, pre, "ungated sweep visits every row");
        // visited rows: bit-identical to the ungated path
        for &j in &live {
            for i in 0..post {
                for b in 0..batch {
                    let k = (j * post + i) * batch + b;
                    assert_eq!(w_gated[k], w_plain[k], "live row {j} diverged");
                }
            }
        }
        // skipped rows: untouched (the documented ε-contract)
        for j in 0..pre {
            if live.contains(&j) {
                continue;
            }
            for i in 0..post {
                for b in 0..batch {
                    assert_eq!(w_gated[(j * post + i) * batch + b], 0.0);
                }
            }
        }
    }

    #[test]
    fn gate_respects_active_mask_and_eps() {
        let p = RuleParams::random(2, 2, 0.4, &mut Pcg64::new(71, 0));
        let cfg = PlasticityConfig {
            presyn_gate: true,
            ..PlasticityConfig::default()
        };
        let batch = 2;
        // row 0 hot only in session 1; row 1 sub-ε everywhere
        let pre_trace = vec![0.0f32, 1.0, 1e-9, 1e-9];
        let post_trace = vec![0.3f32, 0.3, 0.3, 0.3];
        let mut w = vec![0.0f32; 2 * 2 * batch];

        // session 1 masked off → row 0's only hot lane is inactive
        let only0 = crate::snn::spike::mask_words(&[true, false]);
        let visited =
            apply_update_batch(&p, &cfg, batch, &only0, None, &mut w, &pre_trace, &post_trace);
        assert_eq!(visited, 0, "no row has a hot active lane");
        assert!(w.iter().all(|&x| x == 0.0));

        // both sessions active → row 0 hot (via session 1), row 1 still sub-ε
        let both = crate::snn::spike::full_mask(batch);
        let visited =
            apply_update_batch(&p, &cfg, batch, &both, None, &mut w, &pre_trace, &post_trace);
        assert_eq!(visited, 1);
    }

    #[test]
    fn hot_prefilter_short_circuits_without_scanning() {
        // Feed a *deliberately wrong* hot mask (all-cold) against traces
        // that are well above ε: the prefilter must skip every row
        // without ever reaching the value scan — proof that the
        // fast path short-circuits rather than re-deriving the decision
        // from the values. (In the real pipeline the lazy-trace cold
        // invariant makes the mask truthful, so decisions never differ;
        // pinned by tests/lazy_traces.rs against the dense oracle.)
        let pre = 3;
        let post = 2;
        let batch = 2;
        let p = RuleParams::random(pre, post, 0.4, &mut Pcg64::new(72, 0));
        let cfg = PlasticityConfig {
            presyn_gate: true,
            ..PlasticityConfig::default()
        };
        let pre_trace = vec![1.0f32; pre * batch]; // every lane hot by value
        let post_trace = vec![0.5f32; post * batch];
        let mask = crate::snn::spike::full_mask(batch);
        let wpr = mask.len();

        let mut w = vec![0.0f32; pre * post * batch];
        let cold = vec![0u64; pre * wpr];
        let visited =
            apply_update_batch(
                &p, &cfg, batch, &mask, Some(&cold), &mut w, &pre_trace, &post_trace,
            );
        assert_eq!(visited, 0, "all-cold prefilter must skip every row");
        assert!(w.iter().all(|&x| x == 0.0));

        // rows flagged hot fall through to the value scan and update
        let mut hot = vec![0u64; pre * wpr];
        hot[wpr] = 0b11; // row 1 hot in both lanes
        let visited =
            apply_update_batch(&p, &cfg, batch, &mask, Some(&hot), &mut w, &pre_trace, &post_trace);
        assert_eq!(visited, 1);
        for i in 0..post {
            for b in 0..batch {
                assert_ne!(w[(post + i) * batch + b], 0.0, "hot row 1 must update");
            }
        }

        // ε = 0 disables the gate entirely — the prefilter must not skip
        // (the gate is a documented no-op at ε = 0).
        let cfg0 = PlasticityConfig {
            presyn_gate: true,
            trace_eps: 0.0,
            ..PlasticityConfig::default()
        };
        let mut w0 = vec![0.0f32; pre * post * batch];
        let visited = apply_update_batch(
            &p, &cfg0, batch, &mask, Some(&cold), &mut w0, &pre_trace, &post_trace,
        );
        assert_eq!(visited, pre, "ε = 0 must visit every row despite a cold mask");
    }

    #[test]
    fn zero_traces_only_delta_acts() {
        let mut rng = Pcg64::new(4, 0);
        let p = RuleParams::random(2, 2, 0.5, &mut rng);
        let cfg = PlasticityConfig {
            eta: 1.0,
            w_clip: 100.0,
            ..PlasticityConfig::default()
        };
        let mut w = vec![0.0f32; 4];
        apply_update(&p, &cfg, &mut w, &[0.0, 0.0], &[0.0, 0.0]);
        for j in 0..2 {
            for i in 0..2 {
                assert!((w[j * 2 + i] - p.coeffs(j, i)[3]).abs() < 1e-6);
            }
        }
    }
}
