//! The four-term parametric plasticity rule (§II-A) — the paper's core
//! algorithmic contribution:
//!
//! ```text
//! Δw_ij = α_ij·S_j·S_i  +  β_ij·S_j  +  γ_ij·S_i  +  δ_ij
//!         └─ Associative ┘  └ Presyn ┘  └ Postsyn ┘  └ Decay ┘
//! ```
//!
//! θ = {α, β, γ, δ} is learned **offline** by the evolution strategy
//! (Phase 1) and then frozen; **online** (Phase 2) the rule continuously
//! updates the synaptic weights starting from zero.
//!
//! Storage layout matches the hardware: the four coefficient planes are
//! *packed per synapse* (`[α,β,γ,δ]` contiguous) so one wide memory read
//! feeds all four multipliers of the Plasticity Engine — and, in the
//! Pallas kernel, one VMEM tile fetch covers all four terms (see
//! DESIGN.md §Hardware-Adaptation).

use super::numeric::Scalar;
use super::spike::{words_for, LANES};
use crate::util::rng::Pcg64;

/// Per-synapse packed rule coefficients for one layer: `pre × post`
/// synapses, 4 coefficients each, row-major `[pre][post][4]`.
#[derive(Clone, Debug)]
pub struct RuleParams {
    /// Presynaptic population size.
    pub pre: usize,
    /// Postsynaptic population size.
    pub post: usize,
    /// Packed [α, β, γ, δ] × (pre·post), f32 master copy (ES space).
    pub theta: Vec<f32>,
}

/// Coefficients stored per synapse (α, β, γ, δ).
pub const COEFFS_PER_SYNAPSE: usize = 4;

impl RuleParams {
    /// All-zero rule for a `pre × post` synaptic layer.
    pub fn zeros(pre: usize, post: usize) -> Self {
        RuleParams {
            pre,
            post,
            theta: vec![0.0; pre * post * COEFFS_PER_SYNAPSE],
        }
    }

    /// Random initialization for ES seeding: small centered Gaussians.
    pub fn random(pre: usize, post: usize, sigma: f32, rng: &mut Pcg64) -> Self {
        let mut p = Self::zeros(pre, post);
        rng.fill_normal_f32(&mut p.theta, sigma);
        p
    }

    /// Number of f32 parameters in this layer's rule (4 per synapse).
    pub fn n_params(&self) -> usize {
        self.theta.len()
    }

    /// Offset of synapse (j → i)'s packed quadruple inside `theta`.
    #[inline]
    pub fn idx(&self, j_pre: usize, i_post: usize) -> usize {
        (j_pre * self.post + i_post) * COEFFS_PER_SYNAPSE
    }

    /// The packed quadruple for synapse (j → i).
    #[inline]
    pub fn coeffs(&self, j_pre: usize, i_post: usize) -> [f32; 4] {
        let k = self.idx(j_pre, i_post);
        [
            self.theta[k],
            self.theta[k + 1],
            self.theta[k + 2],
            self.theta[k + 3],
        ]
    }

    /// Copy from a flat ES genome segment.
    pub fn load_flat(&mut self, flat: &[f32]) {
        assert_eq!(flat.len(), self.theta.len());
        self.theta.copy_from_slice(flat);
    }

    /// Split coefficient planes: returns (α, β, γ, δ) as `pre×post`
    /// row-major matrices — the layout the XLA artifact consumes
    /// (stacked `[4, pre, post]`).
    pub fn unpack_planes(&self) -> [Vec<f32>; 4] {
        let n = self.pre * self.post;
        let mut planes = [vec![0.0; n], vec![0.0; n], vec![0.0; n], vec![0.0; n]];
        for s in 0..n {
            for c in 0..4 {
                planes[c][s] = self.theta[s * 4 + c];
            }
        }
        planes
    }

    /// Inverse of [`unpack_planes`].
    pub fn from_planes(pre: usize, post: usize, planes: &[Vec<f32>; 4]) -> Self {
        let n = pre * post;
        let mut p = Self::zeros(pre, post);
        for s in 0..n {
            for c in 0..4 {
                p.theta[s * 4 + c] = planes[c][s];
            }
        }
        p
    }
}

/// Hyper-parameters of the online update.
#[derive(Clone, Copy, Debug)]
pub struct PlasticityConfig {
    /// Global learning-rate scale η applied to Δw (the paper folds this
    /// into θ; keeping it explicit lets the ES search a normalized space).
    pub eta: f32,
    /// Symmetric weight clip: w ∈ [−w_clip, +w_clip]. Bounded weights are
    /// what δ's "synaptic regularization" stabilizes; the clip is the
    /// hardware's saturation backstop.
    pub w_clip: f32,
}

impl Default for PlasticityConfig {
    fn default() -> Self {
        PlasticityConfig {
            eta: 0.05,
            w_clip: 4.0,
        }
    }
}

/// Apply one plasticity step to a layer's weight matrix.
///
/// `weights` is `pre × post` row-major. `pre_trace`/`post_trace` are the
/// spike traces *after* this timestep's trace update — the paper computes
/// the synaptic update "based on the spike traces from the current
/// timestep" (§III-C Phase A).
///
/// Generic over the scalar domain so the identical code path serves the
/// f32 golden model and the FP16 FPGA-equivalent model.
pub fn apply_update<S: Scalar>(
    params: &RuleParams,
    cfg: &PlasticityConfig,
    weights: &mut [S],
    pre_trace: &[S],
    post_trace: &[S],
) {
    assert_eq!(weights.len(), params.pre * params.post);
    assert_eq!(pre_trace.len(), params.pre);
    assert_eq!(post_trace.len(), params.post);
    let eta = S::from_f32(cfg.eta);
    let lo = S::from_f32(-cfg.w_clip);
    let hi = S::from_f32(cfg.w_clip);

    for j in 0..params.pre {
        let sj = pre_trace[j];
        let row = j * params.post;
        for i in 0..params.post {
            let si = post_trace[i];
            let k = (row + i) * COEFFS_PER_SYNAPSE;
            let coeffs = [
                S::from_f32(params.theta[k]),
                S::from_f32(params.theta[k + 1]),
                S::from_f32(params.theta[k + 2]),
                S::from_f32(params.theta[k + 3]),
            ];
            let w = &mut weights[row + i];
            *w = update_synapse(coeffs, eta, lo, hi, *w, sj, si);
        }
    }
}

/// Batched plasticity step over `batch` independent sessions sharing one
/// frozen rule θ (the memory-layout point of DESIGN.md §Batched-Serving:
/// θ is 4× the size of a weight matrix, and batching turns its per-step
/// streaming cost from `O(batch)` into `O(1)`).
///
/// Layouts are structure-of-arrays: `weights` is
/// `pre × post × batch` (`[synapse][session]`), traces are
/// `neurons × batch` (`[neuron][session]`). The session mask arrives
/// bit-packed (`active_words`, one bit per session lane — see
/// [`crate::snn::spike::pack_mask_into`]). Full-batch ticks take a
/// mask-free contiguous sweep; partially-active ticks walk only the set
/// mask bits, so masked-off sessions cost nothing and keep their
/// weights untouched. The
/// per-synapse datapath is [`update_synapse`] — the same function the
/// single-session [`apply_update`] uses — with identical operation
/// order, so a batched session is bit-equivalent to a lone network fed
/// the same history.
pub fn apply_update_batch<S: Scalar>(
    params: &RuleParams,
    cfg: &PlasticityConfig,
    batch: usize,
    active_words: &[u64],
    weights: &mut [S],
    pre_trace: &[S],
    post_trace: &[S],
) {
    assert_eq!(weights.len(), params.pre * params.post * batch);
    assert_eq!(pre_trace.len(), params.pre * batch);
    assert_eq!(post_trace.len(), params.post * batch);
    assert_eq!(active_words.len(), words_for(batch), "mask/batch mismatch");
    let eta = S::from_f32(cfg.eta);
    let lo = S::from_f32(-cfg.w_clip);
    let hi = S::from_f32(cfg.w_clip);
    // Full-batch ticks (the serving steady state) take a mask-free inner
    // loop: a branchless contiguous sweep over the session lanes that
    // the compiler can keep in SIMD registers.
    let all_active = active_words.iter().enumerate().all(|(wi, &aw)| {
        let lanes = (batch - wi * LANES).min(LANES);
        let full = if lanes == LANES { u64::MAX } else { (1u64 << lanes) - 1 };
        aw == full
    });

    for j in 0..params.pre {
        let pre_row = &pre_trace[j * batch..(j + 1) * batch];
        let row = j * params.post;
        for i in 0..params.post {
            // One θ fetch serves every session of this synapse.
            let k = (row + i) * COEFFS_PER_SYNAPSE;
            let coeffs = [
                S::from_f32(params.theta[k]),
                S::from_f32(params.theta[k + 1]),
                S::from_f32(params.theta[k + 2]),
                S::from_f32(params.theta[k + 3]),
            ];
            let post_row = &post_trace[i * batch..(i + 1) * batch];
            let wbase = (row + i) * batch;
            let wrow = &mut weights[wbase..wbase + batch];
            if all_active {
                for b in 0..batch {
                    wrow[b] =
                        update_synapse(coeffs, eta, lo, hi, wrow[b], pre_row[b], post_row[b]);
                }
            } else {
                // Partially-active tick: walk only the set mask bits, so
                // the per-synapse cost scales with the number of active
                // sessions, not the provisioned batch.
                for (wi, &aw) in active_words.iter().enumerate() {
                    let mut m = aw;
                    while m != 0 {
                        let b = wi * LANES + m.trailing_zeros() as usize;
                        m &= m - 1;
                        wrow[b] =
                            update_synapse(coeffs, eta, lo, hi, wrow[b], pre_row[b], post_row[b]);
                    }
                }
            }
        }
    }
}

/// One synapse's update — the exact datapath of the Plasticity Engine
/// (four parallel products + pipelined adder tree + scaled saturating
/// accumulate). Shared by the golden model and the FPGA simulator so
/// both are bit-identical by construction:
/// `w' = clamp(w ⊕ η·((α·Sj·Si + β·Sj) + (γ·Si + δ)))`.
#[inline]
pub fn update_synapse<S: Scalar>(
    coeffs: [S; 4],
    eta: S,
    lo: S,
    hi: S,
    w: S,
    sj: S,
    si: S,
) -> S {
    let [alpha, beta, gamma, delta] = coeffs;
    let assoc = alpha.mul(sj).mul(si);
    let presyn = beta.mul(sj);
    let postsyn = gamma.mul(si);
    let t0 = assoc.add(presyn);
    let t1 = postsyn.add(delta);
    let dw = t0.add(t1);
    w.saturating_add(eta.mul(dw)).clamp(lo, hi)
}

/// Reference Δw for a single synapse in f64 (oracle for tests).
pub fn delta_w_reference(coeffs: [f32; 4], sj: f32, si: f32) -> f64 {
    let [a, b, g, d] = coeffs;
    a as f64 * sj as f64 * si as f64 + b as f64 * sj as f64 + g as f64 * si as f64 + d as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fp16::F16;

    fn simple_params() -> RuleParams {
        let mut p = RuleParams::zeros(2, 3);
        // synapse (0,0): pure Hebbian α=1
        let k00 = p.idx(0, 0);
        p.theta[k00] = 1.0;
        // synapse (1,2): pure decay δ=−1
        let k = p.idx(1, 2);
        p.theta[k + 3] = -1.0;
        p
    }

    #[test]
    fn hebbian_term_strengthens_correlated() {
        let p = simple_params();
        let cfg = PlasticityConfig {
            eta: 1.0,
            w_clip: 10.0,
        };
        let mut w = vec![0.0f32; 6];
        let pre = vec![1.0f32, 0.0];
        let post = vec![1.0f32, 0.0, 0.0];
        apply_update(&p, &cfg, &mut w, &pre, &post);
        assert_eq!(w[0], 1.0); // α·1·1
        assert_eq!(w[1], 0.0);
    }

    #[test]
    fn decay_term_reduces_weight_unconditionally() {
        let p = simple_params();
        let cfg = PlasticityConfig {
            eta: 0.5,
            w_clip: 10.0,
        };
        let mut w = vec![0.0f32; 6];
        let pre = vec![0.0f32; 2];
        let post = vec![0.0f32; 3];
        apply_update(&p, &cfg, &mut w, &pre, &post);
        // synapse (1,2) is index 1*3+2 = 5
        assert_eq!(w[5], -0.5);
    }

    #[test]
    fn clip_bounds_weights() {
        let mut p = RuleParams::zeros(1, 1);
        p.theta[1] = 100.0; // huge β
        let cfg = PlasticityConfig {
            eta: 1.0,
            w_clip: 2.0,
        };
        let mut w = vec![0.0f32];
        apply_update(&p, &cfg, &mut w, &[1.0], &[0.0]);
        assert_eq!(w[0], 2.0);
    }

    #[test]
    fn matches_reference_formula() {
        let mut rng = Pcg64::new(1, 0);
        let p = RuleParams::random(4, 5, 0.5, &mut rng);
        let cfg = PlasticityConfig {
            eta: 1.0,
            w_clip: 1e9,
        };
        let mut w = vec![0.0f32; 20];
        let pre: Vec<f32> = (0..4).map(|j| 0.25 * j as f32).collect();
        let post: Vec<f32> = (0..5).map(|i| 0.5 * i as f32).collect();
        apply_update(&p, &cfg, &mut w, &pre, &post);
        for j in 0..4 {
            for i in 0..5 {
                let expect = delta_w_reference(p.coeffs(j, i), pre[j], post[i]);
                let got = w[j * 5 + i] as f64;
                assert!((got - expect).abs() < 1e-5, "({j},{i}): {got} vs {expect}");
            }
        }
    }

    #[test]
    fn f16_update_close_to_f32() {
        let mut rng = Pcg64::new(2, 0);
        let p = RuleParams::random(8, 8, 0.3, &mut rng);
        let cfg = PlasticityConfig::default();
        let mut wf = vec![0.0f32; 64];
        let mut wh = vec![F16::ZERO; 64];
        let pre_f: Vec<f32> = (0..8).map(|j| (j as f32 * 0.3) % 2.0).collect();
        let post_f: Vec<f32> = (0..8).map(|i| (i as f32 * 0.7) % 2.0).collect();
        let pre_h: Vec<F16> = pre_f.iter().map(|&x| F16::from_f32(x)).collect();
        let post_h: Vec<F16> = post_f.iter().map(|&x| F16::from_f32(x)).collect();
        for _ in 0..50 {
            apply_update(&p, &cfg, &mut wf, &pre_f, &post_f);
            apply_update(&p, &cfg, &mut wh, &pre_h, &post_h);
        }
        for k in 0..64 {
            let err = (wf[k] - wh[k].to_f32()).abs();
            assert!(err < 0.05, "synapse {k}: f32 {} vs f16 {}", wf[k], wh[k]);
        }
    }

    #[test]
    fn planes_round_trip() {
        let mut rng = Pcg64::new(3, 0);
        let p = RuleParams::random(3, 7, 1.0, &mut rng);
        let planes = p.unpack_planes();
        let q = RuleParams::from_planes(3, 7, &planes);
        assert_eq!(p.theta, q.theta);
    }

    #[test]
    fn batched_update_matches_sequential_singles() {
        let mut rng = Pcg64::new(11, 0);
        let p = RuleParams::random(5, 4, 0.4, &mut rng);
        let cfg = PlasticityConfig::default();
        let batch = 3;

        // independent per-session traces
        let mut pre_b = vec![0.0f32; 5 * batch];
        let mut post_b = vec![0.0f32; 4 * batch];
        rng.fill_normal_f32(&mut pre_b, 0.8);
        rng.fill_normal_f32(&mut post_b, 0.8);

        let mut w_b = vec![0.0f32; 5 * 4 * batch];
        let mask = crate::snn::spike::mask_words(&[true, true, false]);
        for _ in 0..20 {
            apply_update_batch(&p, &cfg, batch, &mask, &mut w_b, &pre_b, &post_b);
        }

        for b in 0..batch {
            let pre: Vec<f32> = (0..5).map(|j| pre_b[j * batch + b]).collect();
            let post: Vec<f32> = (0..4).map(|i| post_b[i * batch + b]).collect();
            let mut w = vec![0.0f32; 20];
            let steps = if b == 2 { 0 } else { 20 }; // session 2 was masked off
            for _ in 0..steps {
                apply_update(&p, &cfg, &mut w, &pre, &post);
            }
            for s in 0..20 {
                assert_eq!(w_b[s * batch + b], w[s], "session {b} synapse {s}");
            }
        }
    }

    #[test]
    fn zero_traces_only_delta_acts() {
        let mut rng = Pcg64::new(4, 0);
        let p = RuleParams::random(2, 2, 0.5, &mut rng);
        let cfg = PlasticityConfig {
            eta: 1.0,
            w_clip: 100.0,
        };
        let mut w = vec![0.0f32; 4];
        apply_update(&p, &cfg, &mut w, &[0.0, 0.0], &[0.0, 0.0]);
        for j in 0..2 {
            for i in 0..2 {
                assert!((w[j * 2 + i] - p.coeffs(j, i)[3]).abs() < 1e-6);
            }
        }
    }
}
