//! Dense scalar reference implementations — the pre-packing golden path.
//!
//! The serving hot path steps through bit-packed spike words
//! (`spike.rs`, DESIGN.md §Hot-Path). This module keeps the **dense
//! boolean** formulation alive as an independent oracle:
//!
//! - [`ReferenceNetwork`] is a deliberately plain single-session stepper
//!   built from the scalar primitives ([`lif_step_scalar`],
//!   [`trace_step_scalar`], [`apply_update`]) in the canonical order —
//!   the ground truth the packed path must match **bit-for-bit** (pinned
//!   by `tests/packed_equivalence.rs`).
//! - [`DenseBatchedNetwork`] is the dense structure-of-arrays batched
//!   stepper the packed kernels replaced — kept both as a second oracle
//!   and as the "dense" arm of `bench_server_throughput`'s
//!   packed-vs-dense comparison.
//!
//! Nothing here runs on the serving path; clarity beats speed.

use super::lif::lif_step_scalar;
use super::network::{Mode, SnnConfig};
use super::numeric::Scalar;
use super::plasticity::{
    apply_update, update_synapse, PlasticityConfig, RuleParams, COEFFS_PER_SYNAPSE,
};
use super::trace::trace_step_scalar;

/// Dense spike-driven matvec: `out[i] = Σ_j w[j][i] · s_j`. Because
/// spikes are binary this is a gather-accumulate over active rows only —
/// the same event-driven skip the FPGA's psum-stationary dataflow
/// exploits (§III-B: spikes "gate downstream logic"), expressed over a
/// boolean slice.
pub fn matvec_spikes<S: Scalar>(w: &[S], spikes: &[bool], n_post: usize, out: &mut [S]) {
    assert_eq!(out.len(), n_post);
    assert_eq!(w.len(), spikes.len() * n_post);
    for o in out.iter_mut() {
        *o = S::ZERO;
    }
    for (j, &s) in spikes.iter().enumerate() {
        if !s {
            continue;
        }
        let row = &w[j * n_post..(j + 1) * n_post];
        for (o, &wv) in out.iter_mut().zip(row) {
            *o = o.add(wv);
        }
    }
}

/// Dense batched spike-driven matvec over `batch` independent sessions.
///
/// `spikes` is `n_pre × batch` (`[neuron][session]`), `out` is
/// `n_post × batch`. With `shared_w` the weight matrix is the plain
/// `n_pre × n_post` row-major layout used by fixed-weight deployments;
/// otherwise it is `n_pre × n_post × batch` (`[synapse][session]`).
/// Inactive sessions' outputs are zeroed but receive no accumulation.
#[allow(clippy::too_many_arguments)]
pub fn matvec_spikes_batch<S: Scalar>(
    w: &[S],
    shared_w: bool,
    spikes: &[bool],
    n_pre: usize,
    n_post: usize,
    batch: usize,
    active: &[bool],
    out: &mut [S],
) {
    assert_eq!(out.len(), n_post * batch);
    assert_eq!(spikes.len(), n_pre * batch);
    assert_eq!(active.len(), batch);
    let expect_w = if shared_w {
        n_pre * n_post
    } else {
        n_pre * n_post * batch
    };
    assert_eq!(w.len(), expect_w);
    for o in out.iter_mut() {
        *o = S::ZERO;
    }
    for j in 0..n_pre {
        let srow = &spikes[j * batch..(j + 1) * batch];
        // Event-driven skip: rows silent in every active session are free.
        if !srow.iter().zip(active).any(|(&s, &a)| s && a) {
            continue;
        }
        for i in 0..n_post {
            let orow = &mut out[i * batch..(i + 1) * batch];
            if shared_w {
                let wv = w[j * n_post + i];
                for b in 0..batch {
                    if active[b] && srow[b] {
                        orow[b] = orow[b].add(wv);
                    }
                }
            } else {
                let wrow = &w[(j * n_post + i) * batch..(j * n_post + i + 1) * batch];
                for b in 0..batch {
                    if active[b] && srow[b] {
                        orow[b] = orow[b].add(wrow[b]);
                    }
                }
            }
        }
    }
}

/// Dense boolean-masked batched plasticity step — the pre-packing
/// formulation of `apply_update_batch`, kept as the reference oracle.
///
/// Implements the **same presynaptic gate** as the packed path when
/// [`PlasticityConfig::presyn_gate`] is set (skip a row iff every active
/// lane's pre-trace is below `trace_eps`), so gated packed runs are
/// pinned bit-exactly against a gated oracle — the ε-tolerance contract
/// lives between gated and *un*gated runs, never between the two
/// implementations. Returns the number of presynaptic rows visited.
#[allow(clippy::too_many_arguments)]
pub fn apply_update_batch_dense<S: Scalar>(
    params: &RuleParams,
    cfg: &PlasticityConfig,
    batch: usize,
    active: &[bool],
    weights: &mut [S],
    pre_trace: &[S],
    post_trace: &[S],
) -> usize {
    assert_eq!(weights.len(), params.pre * params.post * batch);
    assert_eq!(pre_trace.len(), params.pre * batch);
    assert_eq!(post_trace.len(), params.post * batch);
    assert_eq!(active.len(), batch);
    let eta = S::from_f32(cfg.eta);
    let lo = S::from_f32(-cfg.w_clip);
    let hi = S::from_f32(cfg.w_clip);
    // Ceiling ε quantization — must match `apply_update_batch` exactly
    // (see `Scalar::quantize_threshold` for the coarse-domain rationale).
    let eps = S::quantize_threshold(cfg.trace_eps);
    let mut visited = 0usize;
    for j in 0..params.pre {
        let pre_row = &pre_trace[j * batch..(j + 1) * batch];
        if cfg.presyn_gate
            && pre_row
                .iter()
                .zip(active)
                .all(|(&t, &a)| !a || t < eps)
        {
            continue;
        }
        visited += 1;
        let row = j * params.post;
        for i in 0..params.post {
            let k = (row + i) * COEFFS_PER_SYNAPSE;
            let coeffs = [
                S::from_f32(params.theta[k]),
                S::from_f32(params.theta[k + 1]),
                S::from_f32(params.theta[k + 2]),
                S::from_f32(params.theta[k + 3]),
            ];
            let post_row = &post_trace[i * batch..(i + 1) * batch];
            let wbase = (row + i) * batch;
            let wrow = &mut weights[wbase..wbase + batch];
            for b in 0..batch {
                if !active[b] {
                    continue;
                }
                wrow[b] = update_synapse(coeffs, eta, lo, hi, wrow[b], pre_row[b], post_row[b]);
            }
        }
    }
    visited
}

/// Plain single-session reference stepper: dense matvecs + the scalar
/// LIF/trace primitives + [`apply_update`], executed in the canonical
/// order (L1 forward, hidden LIF, L2 forward, output LIF, traces,
/// plasticity). The packed batched path must match this bit-for-bit for
/// every session.
#[derive(Clone, Debug)]
pub struct ReferenceNetwork<S: Scalar> {
    /// Architecture and dynamics constants.
    pub cfg: SnnConfig,
    /// Plastic (rule θ) or fixed weights.
    pub mode: Mode,
    /// L1 weights, `n_in × n_hidden` row-major.
    pub w1: Vec<S>,
    /// L2 weights, `n_hidden × n_out` row-major.
    pub w2: Vec<S>,
    /// Hidden membrane potentials.
    pub v_hidden: Vec<S>,
    /// Output membrane potentials.
    pub v_out: Vec<S>,
    /// Hidden spikes of the most recent step.
    pub spikes_hidden: Vec<bool>,
    /// Output spikes of the most recent step.
    pub spikes_out: Vec<bool>,
    /// Input-population traces.
    pub trace_in: Vec<S>,
    /// Hidden-population traces.
    pub trace_hidden: Vec<S>,
    /// Output-population traces.
    pub trace_out: Vec<S>,
    /// Soft (subtract V_th) vs hard (zero) reset — mirror of
    /// [`crate::snn::LifLayer::soft_reset`]; set it identically on both
    /// sides when comparing against a packed network.
    pub soft_reset: bool,
    cur_hidden: Vec<S>,
    cur_out: Vec<S>,
}

impl<S: Scalar> ReferenceNetwork<S> {
    /// Fresh reference network (zero weights/state).
    pub fn new(cfg: SnnConfig, mode: Mode) -> Self {
        let (n_in, n_h, n_o) = (cfg.n_in, cfg.n_hidden, cfg.n_out);
        ReferenceNetwork {
            w1: vec![S::ZERO; n_in * n_h],
            w2: vec![S::ZERO; n_h * n_o],
            v_hidden: vec![S::ZERO; n_h],
            v_out: vec![S::ZERO; n_o],
            spikes_hidden: vec![false; n_h],
            spikes_out: vec![false; n_o],
            trace_in: vec![S::ZERO; n_in],
            trace_hidden: vec![S::ZERO; n_h],
            trace_out: vec![S::ZERO; n_o],
            soft_reset: true,
            cur_hidden: vec![S::ZERO; n_h],
            cur_out: vec![S::ZERO; n_o],
            cfg,
            mode,
        }
    }

    /// Install fixed weights from flat `[W1 ‖ W2]` (baseline mode).
    pub fn load_weights(&mut self, flat: &[f32]) {
        assert_eq!(flat.len(), self.cfg.n_weights(), "weight vector mismatch");
        let split = self.cfg.l1_synapses();
        for (w, &x) in self.w1.iter_mut().zip(&flat[..split]) {
            *w = S::from_f32(x);
        }
        for (w, &x) in self.w2.iter_mut().zip(&flat[split..]) {
            *w = S::from_f32(x);
        }
    }

    /// One timestep driven by binary input spikes; returns output spikes.
    pub fn step_spikes(&mut self, input: &[bool]) -> &[bool] {
        assert_eq!(input.len(), self.cfg.n_in);
        let v_th = S::from_f32(self.cfg.v_th);
        let lambda = S::from_f32(self.cfg.lambda);

        // L1 forward + hidden LIF.
        matvec_spikes(&self.w1, input, self.cfg.n_hidden, &mut self.cur_hidden);
        for i in 0..self.cfg.n_hidden {
            let (nv, sp) =
                lif_step_scalar(self.v_hidden[i], self.cur_hidden[i], v_th, self.soft_reset);
            self.v_hidden[i] = nv;
            self.spikes_hidden[i] = sp;
        }

        // L2 forward + output LIF.
        matvec_spikes(&self.w2, &self.spikes_hidden, self.cfg.n_out, &mut self.cur_out);
        for i in 0..self.cfg.n_out {
            let (nv, sp) = lif_step_scalar(self.v_out[i], self.cur_out[i], v_th, self.soft_reset);
            self.v_out[i] = nv;
            self.spikes_out[i] = sp;
        }

        // Traces from the current timestep (§III-C).
        for (t, &s) in self.trace_in.iter_mut().zip(input) {
            *t = trace_step_scalar(*t, s, lambda);
        }
        for (t, &s) in self.trace_hidden.iter_mut().zip(&self.spikes_hidden) {
            *t = trace_step_scalar(*t, s, lambda);
        }
        for (t, &s) in self.trace_out.iter_mut().zip(&self.spikes_out) {
            *t = trace_step_scalar(*t, s, lambda);
        }

        // Plasticity.
        if let Mode::Plastic(rule) = &self.mode {
            apply_update(
                &rule.l1,
                &self.cfg.plasticity,
                &mut self.w1,
                &self.trace_in,
                &self.trace_hidden,
            );
            apply_update(
                &rule.l2,
                &self.cfg.plasticity,
                &mut self.w2,
                &self.trace_hidden,
                &self.trace_out,
            );
        }
        &self.spikes_out
    }
}

/// The dense structure-of-arrays batched stepper the packed kernels
/// replaced: boolean spike matrices, boolean session masks, dense
/// per-lane branches. Semantics are identical to the packed
/// `SnnNetwork::step_spikes_masked`; kept as an oracle and as the dense
/// arm of the packed-vs-dense benchmark.
#[derive(Clone, Debug)]
pub struct DenseBatchedNetwork<S: Scalar> {
    /// Architecture and dynamics constants.
    pub cfg: SnnConfig,
    /// Plastic (shared rule θ, per-session weights) or fixed weights.
    pub mode: Mode,
    /// Number of sessions multiplexed.
    pub batch: usize,
    /// L1 weights (plastic: `[synapse][session]`; fixed: shared row-major).
    pub w1: Vec<S>,
    /// L2 weights, same layout rules as `w1`.
    pub w2: Vec<S>,
    /// Hidden membranes, `[neuron][session]`.
    pub v_hidden: Vec<S>,
    /// Output membranes, `[neuron][session]`.
    pub v_out: Vec<S>,
    /// Hidden spikes, dense `[neuron][session]` booleans.
    pub spikes_hidden: Vec<bool>,
    /// Output spikes, dense `[neuron][session]` booleans.
    pub spikes_out: Vec<bool>,
    /// Input traces, `[neuron][session]`.
    pub trace_in: Vec<S>,
    /// Hidden traces, `[neuron][session]`.
    pub trace_hidden: Vec<S>,
    /// Output traces, `[neuron][session]`.
    pub trace_out: Vec<S>,
    /// Soft vs hard membrane reset (mirror of `LifLayer::soft_reset`).
    pub soft_reset: bool,
    /// Presynaptic rows visited by the most recent step's plasticity
    /// sweep, per synaptic layer `[L1, L2]` (mirror of
    /// `SnnNetwork::plasticity_rows_visited`).
    pub plasticity_rows_visited: [usize; 2],
    cur_hidden: Vec<S>,
    cur_out: Vec<S>,
}

impl<S: Scalar> DenseBatchedNetwork<S> {
    /// Fresh dense batched network (zero weights/state).
    pub fn new(cfg: SnnConfig, mode: Mode, batch: usize) -> Self {
        assert!(batch >= 1, "batch must be >= 1");
        let (n_in, n_h, n_o) = (cfg.n_in, cfg.n_hidden, cfg.n_out);
        let wb = if matches!(mode, Mode::Plastic(_)) { batch } else { 1 };
        DenseBatchedNetwork {
            w1: vec![S::ZERO; n_in * n_h * wb],
            w2: vec![S::ZERO; n_h * n_o * wb],
            v_hidden: vec![S::ZERO; n_h * batch],
            v_out: vec![S::ZERO; n_o * batch],
            spikes_hidden: vec![false; n_h * batch],
            spikes_out: vec![false; n_o * batch],
            trace_in: vec![S::ZERO; n_in * batch],
            trace_hidden: vec![S::ZERO; n_h * batch],
            trace_out: vec![S::ZERO; n_o * batch],
            soft_reset: true,
            plasticity_rows_visited: [0, 0],
            cur_hidden: vec![S::ZERO; n_h * batch],
            cur_out: vec![S::ZERO; n_o * batch],
            cfg,
            mode,
            batch,
        }
    }

    /// Install fixed weights from flat `[W1 ‖ W2]` (baseline mode; the
    /// single shared copy).
    pub fn load_weights(&mut self, flat: &[f32]) {
        assert!(matches!(self.mode, Mode::Fixed), "fixed mode only");
        assert_eq!(flat.len(), self.cfg.n_weights(), "weight vector mismatch");
        let split = self.cfg.l1_synapses();
        for (w, &x) in self.w1.iter_mut().zip(&flat[..split]) {
            *w = S::from_f32(x);
        }
        for (w, &x) in self.w2.iter_mut().zip(&flat[split..]) {
            *w = S::from_f32(x);
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn dense_lif_masked(
        v: &mut [S],
        spikes: &mut [bool],
        currents: &[S],
        v_th: S,
        soft_reset: bool,
        batch: usize,
        active: &[bool],
    ) {
        let neurons = v.len() / batch;
        for i in 0..neurons {
            let row = i * batch;
            for (k, &on) in active.iter().enumerate() {
                if !on {
                    continue;
                }
                let idx = row + k;
                let (nv, sp) = lif_step_scalar(v[idx], currents[idx], v_th, soft_reset);
                v[idx] = nv;
                spikes[idx] = sp;
            }
        }
    }

    fn dense_trace_masked(
        values: &mut [S],
        spikes: &[bool],
        lambda: S,
        batch: usize,
        active: &[bool],
    ) {
        let neurons = values.len() / batch;
        for i in 0..neurons {
            let row = i * batch;
            for (k, &on) in active.iter().enumerate() {
                if !on {
                    continue;
                }
                let idx = row + k;
                let decayed = values[idx].mul(lambda);
                values[idx] = if spikes[idx] { decayed.add(S::ONE) } else { decayed };
            }
        }
    }

    /// One dense batched timestep over the sessions selected by `active`.
    /// `input` is `n_in × batch`, `[neuron][session]`.
    pub fn step_spikes_masked(&mut self, input: &[bool], active: &[bool]) {
        let b = self.batch;
        assert_eq!(input.len(), self.cfg.n_in * b);
        assert_eq!(active.len(), b);
        let shared = matches!(self.mode, Mode::Fixed);
        let v_th = S::from_f32(self.cfg.v_th);
        let lambda = S::from_f32(self.cfg.lambda);

        matvec_spikes_batch(
            &self.w1,
            shared,
            input,
            self.cfg.n_in,
            self.cfg.n_hidden,
            b,
            active,
            &mut self.cur_hidden,
        );
        Self::dense_lif_masked(
            &mut self.v_hidden,
            &mut self.spikes_hidden,
            &self.cur_hidden,
            v_th,
            self.soft_reset,
            b,
            active,
        );

        matvec_spikes_batch(
            &self.w2,
            shared,
            &self.spikes_hidden,
            self.cfg.n_hidden,
            self.cfg.n_out,
            b,
            active,
            &mut self.cur_out,
        );
        Self::dense_lif_masked(
            &mut self.v_out,
            &mut self.spikes_out,
            &self.cur_out,
            v_th,
            self.soft_reset,
            b,
            active,
        );

        Self::dense_trace_masked(&mut self.trace_in, input, lambda, b, active);
        Self::dense_trace_masked(&mut self.trace_hidden, &self.spikes_hidden, lambda, b, active);
        Self::dense_trace_masked(&mut self.trace_out, &self.spikes_out, lambda, b, active);

        if let Mode::Plastic(rule) = &self.mode {
            let v1 = apply_update_batch_dense(
                &rule.l1,
                &self.cfg.plasticity,
                b,
                active,
                &mut self.w1,
                &self.trace_in,
                &self.trace_hidden,
            );
            let v2 = apply_update_batch_dense(
                &rule.l2,
                &self.cfg.plasticity,
                b,
                active,
                &mut self.w2,
                &self.trace_hidden,
                &self.trace_out,
            );
            self.plasticity_rows_visited = [v1, v2];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::NetworkRule;
    use crate::util::rng::Pcg64;

    #[test]
    fn matvec_matches_dense() {
        let mut rng = Pcg64::new(7, 0);
        let (n_pre, n_post) = (13, 9);
        let mut w = vec![0.0f32; n_pre * n_post];
        rng.fill_normal_f32(&mut w, 1.0);
        let spikes: Vec<bool> = (0..n_pre).map(|_| rng.bernoulli(0.4)).collect();
        let mut out = vec![0.0f32; n_post];
        matvec_spikes(&w, &spikes, n_post, &mut out);
        for i in 0..n_post {
            let mut expect = 0.0;
            for j in 0..n_pre {
                if spikes[j] {
                    expect += w[j * n_post + i];
                }
            }
            assert!((out[i] - expect).abs() < 1e-5);
        }
    }

    #[test]
    fn dense_batched_matches_scalar_reference() {
        // The two oracles must agree with each other bit-for-bit.
        let cfg = SnnConfig::tiny();
        let batch = 3;
        let mut rng = Pcg64::new(77, 0);
        let mut flat = vec![0.0f32; cfg.n_rule_params()];
        rng.fill_normal_f32(&mut flat, 0.25);
        let rule = NetworkRule::from_flat(&cfg, &flat);

        let mut dense =
            DenseBatchedNetwork::<f32>::new(cfg.clone(), Mode::Plastic(rule.clone().into()), batch);
        let mut refs: Vec<ReferenceNetwork<f32>> = (0..batch)
            .map(|_| ReferenceNetwork::new(cfg.clone(), Mode::Plastic(rule.clone().into())))
            .collect();

        let active = vec![true; batch];
        let mut input_rng = Pcg64::new(78, 0);
        for _ in 0..30 {
            let mut inmat = vec![false; cfg.n_in * batch];
            for v in inmat.iter_mut() {
                *v = input_rng.bernoulli(0.4);
            }
            dense.step_spikes_masked(&inmat, &active);
            for (b, r) in refs.iter_mut().enumerate() {
                let single: Vec<bool> = (0..cfg.n_in).map(|j| inmat[j * batch + b]).collect();
                r.step_spikes(&single);
                for o in 0..cfg.n_out {
                    assert_eq!(dense.spikes_out[o * batch + b], r.spikes_out[o]);
                }
            }
        }
        for (b, r) in refs.iter().enumerate() {
            for s in 0..cfg.l1_synapses() {
                assert_eq!(dense.w1[s * batch + b], r.w1[s], "w1 s{b} syn{s}");
            }
            for o in 0..cfg.n_out {
                assert_eq!(dense.trace_out[o * batch + b], r.trace_out[o]);
            }
        }
    }
}
