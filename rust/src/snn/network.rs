//! The three-layer fully connected SNN controller (§IV-A: input →
//! 128 hidden → output for control; 784 → 1024 → 10 for MNIST).
//!
//! "Three-layer" counts neuron populations; there are **two synaptic
//! layers** — exactly the L1/L2 pair the hardware pipeline overlaps
//! (§III-C). The network is purely feed-forward, stepped once per control
//! tick:
//!
//! 1. L1 forward: hidden currents = Wᵀ₁ · s_in, LIF update, hidden spikes
//! 2. L2 forward: output currents = Wᵀ₂ · s_hid, LIF update, output spikes
//! 3. trace updates on all three populations
//! 4. (plastic mode) apply the four-term rule to W₁ and W₂
//!
//! Weights start at **zero** in plastic mode (§II-B Phase 2): all task
//! competence emerges online from the learned rule.

use super::lif::LifLayer;
use super::numeric::Scalar;
use super::plasticity::{apply_update, PlasticityConfig, RuleParams};
use super::trace::TraceVector;

/// Static architecture + dynamics constants.
#[derive(Clone, Debug)]
pub struct SnnConfig {
    pub n_in: usize,
    pub n_hidden: usize,
    pub n_out: usize,
    /// Trace decay λ (default 0.5 — a shift in hardware).
    pub lambda: f32,
    /// LIF threshold.
    pub v_th: f32,
    /// Input current gain applied to encoded observations.
    pub input_gain: f32,
    pub plasticity: PlasticityConfig,
}

impl SnnConfig {
    pub fn control(n_in: usize, n_out: usize) -> Self {
        SnnConfig {
            n_in,
            n_hidden: 128,
            n_out,
            lambda: 0.5,
            v_th: 1.0,
            input_gain: 2.0,
            plasticity: PlasticityConfig::default(),
        }
    }

    pub fn mnist() -> Self {
        SnnConfig {
            n_in: 784,
            n_hidden: 1024,
            n_out: 10,
            lambda: 0.5,
            v_th: 1.0,
            input_gain: 2.0,
            plasticity: PlasticityConfig::default(),
        }
    }

    /// Small architecture for tests and the FPGA unit benches.
    pub fn tiny() -> Self {
        SnnConfig {
            n_in: 8,
            n_hidden: 16,
            n_out: 4,
            lambda: 0.5,
            v_th: 1.0,
            input_gain: 2.0,
            plasticity: PlasticityConfig::default(),
        }
    }

    pub fn l1_synapses(&self) -> usize {
        self.n_in * self.n_hidden
    }

    pub fn l2_synapses(&self) -> usize {
        self.n_hidden * self.n_out
    }

    /// Total θ dimension for the ES genome (both layers).
    pub fn n_rule_params(&self) -> usize {
        4 * (self.l1_synapses() + self.l2_synapses())
    }

    /// Total weight count (for the weight-trained baseline genome).
    pub fn n_weights(&self) -> usize {
        self.l1_synapses() + self.l2_synapses()
    }
}

/// The frozen learning rule for both synaptic layers (Phase-1 output).
#[derive(Clone, Debug)]
pub struct NetworkRule {
    pub l1: RuleParams,
    pub l2: RuleParams,
}

impl NetworkRule {
    pub fn zeros(cfg: &SnnConfig) -> Self {
        NetworkRule {
            l1: RuleParams::zeros(cfg.n_in, cfg.n_hidden),
            l2: RuleParams::zeros(cfg.n_hidden, cfg.n_out),
        }
    }

    /// Load from a flat ES genome: `[θ_L1 ‖ θ_L2]`.
    pub fn from_flat(cfg: &SnnConfig, flat: &[f32]) -> Self {
        assert_eq!(flat.len(), cfg.n_rule_params(), "genome length mismatch");
        let mut rule = Self::zeros(cfg);
        let split = 4 * cfg.l1_synapses();
        rule.l1.load_flat(&flat[..split]);
        rule.l2.load_flat(&flat[split..]);
        rule
    }

    pub fn to_flat(&self) -> Vec<f32> {
        let mut v = Vec::with_capacity(self.l1.theta.len() + self.l2.theta.len());
        v.extend_from_slice(&self.l1.theta);
        v.extend_from_slice(&self.l2.theta);
        v
    }
}

/// How synaptic weights evolve during an episode.
#[derive(Clone, Debug)]
pub enum Mode {
    /// Phase-2 FireFly-P: zero-initialized weights + online rule updates.
    Plastic(NetworkRule),
    /// Baseline: fixed, directly trained weights; no online updates.
    Fixed,
}

/// Full mutable network state, generic over the arithmetic domain.
#[derive(Clone, Debug)]
pub struct SnnNetwork<S: Scalar> {
    pub cfg: SnnConfig,
    pub mode: Mode,
    /// L1 weights, `n_in × n_hidden` row-major.
    pub w1: Vec<S>,
    /// L2 weights, `n_hidden × n_out` row-major.
    pub w2: Vec<S>,
    pub hidden: LifLayer<S>,
    pub output: LifLayer<S>,
    pub trace_in: TraceVector<S>,
    pub trace_hidden: TraceVector<S>,
    pub trace_out: TraceVector<S>,
    /// Input spike staging (set by `step`).
    in_spikes: Vec<bool>,
    /// Scratch current buffers (allocation-free steady state).
    cur_hidden: Vec<S>,
    cur_out: Vec<S>,
    pub steps: u64,
}

impl<S: Scalar> SnnNetwork<S> {
    pub fn new(cfg: SnnConfig, mode: Mode) -> Self {
        let (n_in, n_h, n_o) = (cfg.n_in, cfg.n_hidden, cfg.n_out);
        let lambda = cfg.lambda;
        let v_th = cfg.v_th;
        SnnNetwork {
            w1: vec![S::ZERO; n_in * n_h],
            w2: vec![S::ZERO; n_h * n_o],
            hidden: LifLayer::new(n_h, v_th),
            output: LifLayer::new(n_o, v_th),
            trace_in: TraceVector::new(n_in, lambda),
            trace_hidden: TraceVector::new(n_h, lambda),
            trace_out: TraceVector::new(n_o, lambda),
            in_spikes: vec![false; n_in],
            cur_hidden: vec![S::ZERO; n_h],
            cur_out: vec![S::ZERO; n_o],
            steps: 0,
            cfg,
            mode,
        }
    }

    /// Install fixed weights (baseline mode) from flat `[W1 ‖ W2]`.
    pub fn load_weights(&mut self, flat: &[f32]) {
        assert_eq!(flat.len(), self.cfg.n_weights(), "weight vector mismatch");
        let split = self.cfg.l1_synapses();
        for (w, &x) in self.w1.iter_mut().zip(&flat[..split]) {
            *w = S::from_f32(x);
        }
        for (w, &x) in self.w2.iter_mut().zip(&flat[split..]) {
            *w = S::from_f32(x);
        }
    }

    /// Reset all dynamic state (weights too, in plastic mode — Phase 2
    /// starts every deployment from w = 0).
    pub fn reset(&mut self) {
        if matches!(self.mode, Mode::Plastic(_)) {
            for w in self.w1.iter_mut() {
                *w = S::ZERO;
            }
            for w in self.w2.iter_mut() {
                *w = S::ZERO;
            }
        }
        self.hidden.reset();
        self.output.reset();
        self.trace_in.reset();
        self.trace_hidden.reset();
        self.trace_out.reset();
        self.steps = 0;
    }

    /// One network timestep driven by already-binary input spikes.
    /// Returns a reference to the output spike vector.
    pub fn step_spikes(&mut self, input_spikes: &[bool]) -> &[bool] {
        assert_eq!(input_spikes.len(), self.cfg.n_in);
        self.in_spikes.copy_from_slice(input_spikes);

        // --- L1 forward: psum accumulation (Wᵀ·s), LIF, spike ----------
        matvec_spikes(
            &self.w1,
            &self.in_spikes,
            self.cfg.n_hidden,
            &mut self.cur_hidden,
        );
        self.hidden.step(&self.cur_hidden);

        // --- L2 forward -------------------------------------------------
        matvec_spikes(
            &self.w2,
            &self.hidden.spikes,
            self.cfg.n_out,
            &mut self.cur_out,
        );
        self.output.step(&self.cur_out);

        // --- Trace updates (current timestep, §III-C) --------------------
        self.trace_in.update(&self.in_spikes);
        self.trace_hidden.update(&self.hidden.spikes);
        self.trace_out.update(&self.output.spikes);

        // --- Plasticity -------------------------------------------------
        if let Mode::Plastic(rule) = &self.mode {
            apply_update(
                &rule.l1,
                &self.cfg.plasticity,
                &mut self.w1,
                &self.trace_in.values,
                &self.trace_hidden.values,
            );
            apply_update(
                &rule.l2,
                &self.cfg.plasticity,
                &mut self.w2,
                &self.trace_hidden.values,
                &self.trace_out.values,
            );
        }

        self.steps += 1;
        &self.output.spikes
    }

    /// One timestep driven by analog input currents: each input neuron is
    /// a probabilistic/threshold encoder handled upstream; here values in
    /// [0, 1] are compared against a fixed 0.5 threshold — the
    /// deterministic current encoder used by the control stack (see
    /// `encoding::CurrentEncoder` for richer schemes).
    pub fn step_currents(&mut self, currents01: &[f32]) -> &[bool] {
        assert_eq!(currents01.len(), self.cfg.n_in);
        // reuse in_spikes staging through a local to satisfy the borrow
        let spikes: Vec<bool> = currents01.iter().map(|&c| c > 0.5).collect();
        self.step_spikes(&spikes)
    }

    /// Output trace snapshot as f32 (decoder input).
    pub fn output_traces_f32(&self) -> Vec<f32> {
        self.trace_out.values.iter().map(|v| v.to_f32()).collect()
    }

    /// L∞ norm of the weight matrices (stability diagnostics).
    pub fn weight_linf(&self) -> f32 {
        self.w1
            .iter()
            .chain(self.w2.iter())
            .map(|w| w.to_f32().abs())
            .fold(0.0, f32::max)
    }

    /// Mean absolute weight (activity diagnostics).
    pub fn weight_mean_abs(&self) -> f32 {
        let total: f32 = self
            .w1
            .iter()
            .chain(self.w2.iter())
            .map(|w| w.to_f32().abs())
            .sum();
        total / (self.w1.len() + self.w2.len()) as f32
    }
}

/// Spike-driven matvec: `out[i] = Σ_j w[j][i] · s_j`. Because spikes are
/// binary this is a gather-accumulate over active rows only — the same
/// event-driven skip the FPGA's psum-stationary dataflow exploits (§III-B:
/// spikes "gate downstream logic").
pub fn matvec_spikes<S: Scalar>(w: &[S], spikes: &[bool], n_post: usize, out: &mut [S]) {
    assert_eq!(out.len(), n_post);
    assert_eq!(w.len(), spikes.len() * n_post);
    for o in out.iter_mut() {
        *o = S::ZERO;
    }
    for (j, &s) in spikes.iter().enumerate() {
        if !s {
            continue;
        }
        let row = &w[j * n_post..(j + 1) * n_post];
        for (o, &wv) in out.iter_mut().zip(row) {
            *o = o.add(wv);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fp16::F16;
    use crate::util::rng::Pcg64;

    #[test]
    fn zero_weights_silent_without_rule() {
        let cfg = SnnConfig::tiny();
        let mut net = SnnNetwork::<f32>::new(cfg.clone(), Mode::Fixed);
        let spikes = vec![true; cfg.n_in];
        for _ in 0..10 {
            let out = net.step_spikes(&spikes);
            assert!(out.iter().all(|&s| !s));
        }
    }

    #[test]
    fn presynaptic_rule_bootstraps_from_zero() {
        // β > 0 on L1 grows weights from input activity alone, eventually
        // driving hidden spikes — the bootstrapping path Phase 2 relies on.
        let cfg = SnnConfig::tiny();
        let mut rule = NetworkRule::zeros(&cfg);
        for s in 0..cfg.l1_synapses() {
            rule.l1.theta[s * 4 + 1] = 0.5; // β
        }
        for s in 0..cfg.l2_synapses() {
            rule.l2.theta[s * 4 + 1] = 0.5;
        }
        let mut net = SnnNetwork::<f32>::new(cfg.clone(), Mode::Plastic(rule));
        let spikes = vec![true; cfg.n_in];
        let mut hidden_fired = false;
        let mut out_fired = false;
        for _ in 0..100 {
            net.step_spikes(&spikes);
            hidden_fired |= net.hidden.spikes.iter().any(|&s| s);
            out_fired |= net.output.spikes.iter().any(|&s| s);
        }
        assert!(hidden_fired, "hidden layer never fired");
        assert!(out_fired, "output layer never fired");
        assert!(net.weight_mean_abs() > 0.0);
    }

    #[test]
    fn delta_decay_keeps_weights_bounded() {
        let cfg = SnnConfig::tiny();
        let mut rule = NetworkRule::zeros(&cfg);
        for s in 0..cfg.l1_synapses() {
            rule.l1.theta[s * 4 + 1] = 1.0; // strong growth
            rule.l1.theta[s * 4 + 3] = -0.2; // regularization
        }
        let mut net = SnnNetwork::<f32>::new(cfg.clone(), Mode::Plastic(rule));
        let spikes = vec![true; cfg.n_in];
        for _ in 0..500 {
            net.step_spikes(&spikes);
        }
        assert!(net.weight_linf() <= net.cfg.plasticity.w_clip + 1e-6);
        assert!(net.weight_linf().is_finite());
    }

    #[test]
    fn reset_zeroes_plastic_weights_but_keeps_fixed() {
        let cfg = SnnConfig::tiny();
        let mut rng = Pcg64::new(5, 0);

        let mut fixed = SnnNetwork::<f32>::new(cfg.clone(), Mode::Fixed);
        let mut flat = vec![0.0f32; cfg.n_weights()];
        rng.fill_normal_f32(&mut flat, 1.0);
        fixed.load_weights(&flat);
        fixed.reset();
        assert!(fixed.weight_mean_abs() > 0.0, "fixed weights must survive reset");

        let rule = NetworkRule::zeros(&cfg);
        let mut plastic = SnnNetwork::<f32>::new(cfg.clone(), Mode::Plastic(rule));
        plastic.w1[0] = 1.0;
        plastic.reset();
        assert_eq!(plastic.w1[0], 0.0);
    }

    #[test]
    fn genome_round_trip() {
        let cfg = SnnConfig::tiny();
        let mut rng = Pcg64::new(6, 0);
        let mut flat = vec![0.0f32; cfg.n_rule_params()];
        rng.fill_normal_f32(&mut flat, 0.3);
        let rule = NetworkRule::from_flat(&cfg, &flat);
        assert_eq!(rule.to_flat(), flat);
    }

    #[test]
    fn matvec_matches_dense() {
        let mut rng = Pcg64::new(7, 0);
        let (n_pre, n_post) = (13, 9);
        let mut w = vec![0.0f32; n_pre * n_post];
        rng.fill_normal_f32(&mut w, 1.0);
        let spikes: Vec<bool> = (0..n_pre).map(|_| rng.bernoulli(0.4)).collect();
        let mut out = vec![0.0f32; n_post];
        matvec_spikes(&w, &spikes, n_post, &mut out);
        for i in 0..n_post {
            let mut expect = 0.0;
            for j in 0..n_pre {
                if spikes[j] {
                    expect += w[j * n_post + i];
                }
            }
            assert!((out[i] - expect).abs() < 1e-5);
        }
    }

    #[test]
    fn f16_network_tracks_f32_network() {
        let cfg = SnnConfig::tiny();
        let mut rng = Pcg64::new(8, 0);
        let mut flat = vec![0.0f32; cfg.n_rule_params()];
        rng.fill_normal_f32(&mut flat, 0.2);
        let rule = NetworkRule::from_flat(&cfg, &flat);

        let mut a = SnnNetwork::<f32>::new(cfg.clone(), Mode::Plastic(rule.clone()));
        let mut b = SnnNetwork::<F16>::new(cfg.clone(), Mode::Plastic(rule));
        let mut input_rng = Pcg64::new(9, 0);
        let mut spike_agreement = 0usize;
        let mut total = 0usize;
        for _ in 0..60 {
            let spikes: Vec<bool> = (0..cfg.n_in).map(|_| input_rng.bernoulli(0.5)).collect();
            let oa: Vec<bool> = a.step_spikes(&spikes).to_vec();
            let ob: Vec<bool> = b.step_spikes(&spikes).to_vec();
            spike_agreement += oa.iter().zip(&ob).filter(|(x, y)| x == y).count();
            total += oa.len();
        }
        // FP16 quantization may flip borderline spikes, but behaviour
        // must stay closely aligned (paper argues FP16 suffices).
        let agreement = spike_agreement as f64 / total as f64;
        assert!(agreement > 0.9, "spike agreement only {agreement}");
    }

    #[test]
    fn steady_state_step_is_allocation_free_observable() {
        // Proxy check: repeated stepping does not grow weight/trace
        // buffer lengths (we can't intercept the allocator, but we pin
        // the state sizes the hot loop touches).
        let cfg = SnnConfig::tiny();
        let rule = NetworkRule::zeros(&cfg);
        let mut net = SnnNetwork::<f32>::new(cfg.clone(), Mode::Plastic(rule));
        let spikes = vec![true; cfg.n_in];
        let w1_cap = net.w1.capacity();
        for _ in 0..100 {
            net.step_spikes(&spikes);
        }
        assert_eq!(net.w1.capacity(), w1_cap);
        assert_eq!(net.steps, 100);
    }
}
