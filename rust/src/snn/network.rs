//! The three-layer fully connected SNN controller (§IV-A: input →
//! 128 hidden → output for control; 784 → 1024 → 10 for MNIST).
//!
//! "Three-layer" counts neuron populations; there are **two synaptic
//! layers** — exactly the L1/L2 pair the hardware pipeline overlaps
//! (§III-C). The network is purely feed-forward, stepped once per control
//! tick:
//!
//! 1. L1 forward: hidden currents = Wᵀ₁ · s_in, LIF update, hidden spikes
//! 2. L2 forward: output currents = Wᵀ₂ · s_hid, LIF update, output spikes
//! 3. trace updates on all three populations
//! 4. (plastic mode) apply the four-term rule to W₁ and W₂
//!
//! Weights start at **zero** in plastic mode (§II-B Phase 2): all task
//! competence emerges online from the learned rule.

use super::lif::LifLayer;
use super::numeric::Scalar;
use super::plasticity::{apply_update, apply_update_batch, PlasticityConfig, RuleParams};
use super::trace::TraceVector;

/// Static architecture + dynamics constants.
#[derive(Clone, Debug)]
pub struct SnnConfig {
    /// Input population size (encoder neurons).
    pub n_in: usize,
    /// Hidden population size (paper: 128 for control, 1024 for MNIST).
    pub n_hidden: usize,
    /// Output population size (decoder neurons).
    pub n_out: usize,
    /// Trace decay λ (default 0.5 — a shift in hardware).
    pub lambda: f32,
    /// LIF threshold.
    pub v_th: f32,
    /// Input current gain applied to encoded observations.
    pub input_gain: f32,
    /// Online-update hyper-parameters (η scale and weight clip).
    pub plasticity: PlasticityConfig,
}

impl SnnConfig {
    /// Control-geometry config: `n_in → 128 → n_out` with paper defaults.
    pub fn control(n_in: usize, n_out: usize) -> Self {
        SnnConfig {
            n_in,
            n_hidden: 128,
            n_out,
            lambda: 0.5,
            v_th: 1.0,
            input_gain: 2.0,
            plasticity: PlasticityConfig::default(),
        }
    }

    /// Table-II MNIST geometry: 784 → 1024 → 10.
    pub fn mnist() -> Self {
        SnnConfig {
            n_in: 784,
            n_hidden: 1024,
            n_out: 10,
            lambda: 0.5,
            v_th: 1.0,
            input_gain: 2.0,
            plasticity: PlasticityConfig::default(),
        }
    }

    /// Small architecture for tests and the FPGA unit benches.
    pub fn tiny() -> Self {
        SnnConfig {
            n_in: 8,
            n_hidden: 16,
            n_out: 4,
            lambda: 0.5,
            v_th: 1.0,
            input_gain: 2.0,
            plasticity: PlasticityConfig::default(),
        }
    }

    /// Synapse count of the input → hidden layer.
    pub fn l1_synapses(&self) -> usize {
        self.n_in * self.n_hidden
    }

    /// Synapse count of the hidden → output layer.
    pub fn l2_synapses(&self) -> usize {
        self.n_hidden * self.n_out
    }

    /// Total θ dimension for the ES genome (both layers).
    pub fn n_rule_params(&self) -> usize {
        4 * (self.l1_synapses() + self.l2_synapses())
    }

    /// Total weight count (for the weight-trained baseline genome).
    pub fn n_weights(&self) -> usize {
        self.l1_synapses() + self.l2_synapses()
    }
}

/// The frozen learning rule for both synaptic layers (Phase-1 output).
#[derive(Clone, Debug)]
pub struct NetworkRule {
    /// Rule coefficients for the input → hidden synapses.
    pub l1: RuleParams,
    /// Rule coefficients for the hidden → output synapses.
    pub l2: RuleParams,
}

impl NetworkRule {
    /// All-zero rule (no plasticity) sized for `cfg`.
    pub fn zeros(cfg: &SnnConfig) -> Self {
        NetworkRule {
            l1: RuleParams::zeros(cfg.n_in, cfg.n_hidden),
            l2: RuleParams::zeros(cfg.n_hidden, cfg.n_out),
        }
    }

    /// Load from a flat ES genome: `[θ_L1 ‖ θ_L2]`.
    pub fn from_flat(cfg: &SnnConfig, flat: &[f32]) -> Self {
        assert_eq!(flat.len(), cfg.n_rule_params(), "genome length mismatch");
        let mut rule = Self::zeros(cfg);
        let split = 4 * cfg.l1_synapses();
        rule.l1.load_flat(&flat[..split]);
        rule.l2.load_flat(&flat[split..]);
        rule
    }

    /// Serialize back to the flat ES genome layout `[θ_L1 ‖ θ_L2]`.
    pub fn to_flat(&self) -> Vec<f32> {
        let mut v = Vec::with_capacity(self.l1.theta.len() + self.l2.theta.len());
        v.extend_from_slice(&self.l1.theta);
        v.extend_from_slice(&self.l2.theta);
        v
    }
}

/// How synaptic weights evolve during an episode.
#[derive(Clone, Debug)]
pub enum Mode {
    /// Phase-2 FireFly-P: zero-initialized weights + online rule updates.
    Plastic(NetworkRule),
    /// Baseline: fixed, directly trained weights; no online updates.
    Fixed,
}

/// Full mutable network state, generic over the arithmetic domain.
///
/// Carries a structure-of-arrays **batch dimension** for multi-session
/// serving (DESIGN.md §Batched-Serving). One network instance holds
/// `batch` independent controller sessions that share the static parts —
/// the config, and in plastic mode the frozen rule θ (by far the largest
/// array: 4 f32 per synapse) — while membranes, traces, and (in plastic
/// mode) the evolving weights are per-session, interleaved
/// `[element][session]`. `batch == 1` (the [`SnnNetwork::new`] default)
/// is byte-identical to the historical single-session layout.
///
/// In [`Mode::Fixed`] the weights never change, so they are stored once
/// (`n_in × n_hidden`, no batch dimension) and shared by every session.
#[derive(Clone, Debug)]
pub struct SnnNetwork<S: Scalar> {
    /// Static architecture and dynamics constants.
    pub cfg: SnnConfig,
    /// Plastic (shared rule θ, per-session weights) or fixed weights.
    pub mode: Mode,
    /// L1 weights. Plastic: `n_in × n_hidden × batch`, laid out
    /// `[synapse][session]`. Fixed: `n_in × n_hidden` row-major, shared
    /// across sessions.
    pub w1: Vec<S>,
    /// L2 weights; same layout rules as `w1` with `n_hidden × n_out`.
    pub w2: Vec<S>,
    /// Hidden LIF population (batched).
    pub hidden: LifLayer<S>,
    /// Output LIF population (batched).
    pub output: LifLayer<S>,
    /// Input-population spike traces (batched).
    pub trace_in: TraceVector<S>,
    /// Hidden-population spike traces (batched).
    pub trace_hidden: TraceVector<S>,
    /// Output-population spike traces (batched).
    pub trace_out: TraceVector<S>,
    /// Number of independent sessions this instance multiplexes.
    pub batch: usize,
    /// Input spike staging (set by `step`).
    in_spikes: Vec<bool>,
    /// Scratch current buffers (allocation-free steady state).
    cur_hidden: Vec<S>,
    cur_out: Vec<S>,
    /// Timesteps executed (batched steps count once).
    pub steps: u64,
}

impl<S: Scalar> SnnNetwork<S> {
    /// Single-session network (the historical constructor).
    pub fn new(cfg: SnnConfig, mode: Mode) -> Self {
        Self::new_batched(cfg, mode, 1)
    }

    /// Network multiplexing `batch` independent sessions in
    /// structure-of-arrays layout. All sessions share `cfg` and the rule
    /// θ; each has its own membrane/trace (and, in plastic mode, weight)
    /// state.
    pub fn new_batched(cfg: SnnConfig, mode: Mode, batch: usize) -> Self {
        assert!(batch >= 1, "batch must be >= 1");
        let (n_in, n_h, n_o) = (cfg.n_in, cfg.n_hidden, cfg.n_out);
        let lambda = cfg.lambda;
        let v_th = cfg.v_th;
        // Fixed weights are session-invariant: store one copy.
        let wb = if matches!(mode, Mode::Plastic(_)) { batch } else { 1 };
        SnnNetwork {
            w1: vec![S::ZERO; n_in * n_h * wb],
            w2: vec![S::ZERO; n_h * n_o * wb],
            hidden: LifLayer::batched(n_h, batch, v_th),
            output: LifLayer::batched(n_o, batch, v_th),
            trace_in: TraceVector::batched(n_in, batch, lambda),
            trace_hidden: TraceVector::batched(n_h, batch, lambda),
            trace_out: TraceVector::batched(n_o, batch, lambda),
            in_spikes: vec![false; n_in * batch],
            cur_hidden: vec![S::ZERO; n_h * batch],
            cur_out: vec![S::ZERO; n_o * batch],
            steps: 0,
            batch,
            cfg,
            mode,
        }
    }

    /// Whether `w1`/`w2` are stored once and shared by every session
    /// (fixed mode) rather than per-session (plastic mode).
    #[inline]
    pub fn weights_shared(&self) -> bool {
        matches!(self.mode, Mode::Fixed)
    }

    /// Install fixed weights (baseline mode) from flat `[W1 ‖ W2]`.
    pub fn load_weights(&mut self, flat: &[f32]) {
        assert_eq!(flat.len(), self.cfg.n_weights(), "weight vector mismatch");
        let split = self.cfg.l1_synapses();
        for (w, &x) in self.w1.iter_mut().zip(&flat[..split]) {
            *w = S::from_f32(x);
        }
        for (w, &x) in self.w2.iter_mut().zip(&flat[split..]) {
            *w = S::from_f32(x);
        }
    }

    /// Reset all dynamic state of **every** session (weights too, in
    /// plastic mode — Phase 2 starts every deployment from w = 0).
    pub fn reset(&mut self) {
        if matches!(self.mode, Mode::Plastic(_)) {
            for w in self.w1.iter_mut() {
                *w = S::ZERO;
            }
            for w in self.w2.iter_mut() {
                *w = S::ZERO;
            }
        }
        self.hidden.reset();
        self.output.reset();
        self.trace_in.reset();
        self.trace_hidden.reset();
        self.trace_out.reset();
        self.steps = 0;
    }

    /// Reset one session's dynamic state (its weight column too, in
    /// plastic mode), leaving every other session untouched.
    pub fn reset_session(&mut self, session: usize) {
        assert!(session < self.batch, "session out of range");
        if matches!(self.mode, Mode::Plastic(_)) {
            let b = self.batch;
            for s in 0..self.cfg.l1_synapses() {
                self.w1[s * b + session] = S::ZERO;
            }
            for s in 0..self.cfg.l2_synapses() {
                self.w2[s * b + session] = S::ZERO;
            }
        }
        self.hidden.reset_session(session);
        self.output.reset_session(session);
        self.trace_in.reset_session(session);
        self.trace_hidden.reset_session(session);
        self.trace_out.reset_session(session);
    }

    /// One network timestep driven by already-binary input spikes.
    /// Returns a reference to the output spike vector. Single-session
    /// instances only; batched instances use
    /// [`SnnNetwork::step_spikes_masked`].
    pub fn step_spikes(&mut self, input_spikes: &[bool]) -> &[bool] {
        assert_eq!(self.batch, 1, "batched networks step via step_spikes_masked");
        assert_eq!(input_spikes.len(), self.cfg.n_in);
        self.in_spikes.copy_from_slice(input_spikes);

        // --- L1 forward: psum accumulation (Wᵀ·s), LIF, spike ----------
        matvec_spikes(
            &self.w1,
            &self.in_spikes,
            self.cfg.n_hidden,
            &mut self.cur_hidden,
        );
        self.hidden.step(&self.cur_hidden);

        // --- L2 forward -------------------------------------------------
        matvec_spikes(
            &self.w2,
            &self.hidden.spikes,
            self.cfg.n_out,
            &mut self.cur_out,
        );
        self.output.step(&self.cur_out);

        // --- Trace updates (current timestep, §III-C) --------------------
        self.trace_in.update(&self.in_spikes);
        self.trace_hidden.update(&self.hidden.spikes);
        self.trace_out.update(&self.output.spikes);

        // --- Plasticity -------------------------------------------------
        if let Mode::Plastic(rule) = &self.mode {
            apply_update(
                &rule.l1,
                &self.cfg.plasticity,
                &mut self.w1,
                &self.trace_in.values,
                &self.trace_hidden.values,
            );
            apply_update(
                &rule.l2,
                &self.cfg.plasticity,
                &mut self.w2,
                &self.trace_hidden.values,
                &self.trace_out.values,
            );
        }

        self.steps += 1;
        &self.output.spikes
    }

    /// One timestep driven by analog input currents: each input neuron is
    /// a probabilistic/threshold encoder handled upstream; here values in
    /// [0, 1] are compared against a fixed 0.5 threshold — the
    /// deterministic current encoder used by the control stack (see
    /// `encoding::CurrentEncoder` for richer schemes).
    pub fn step_currents(&mut self, currents01: &[f32]) -> &[bool] {
        assert_eq!(currents01.len(), self.cfg.n_in);
        // reuse in_spikes staging through a local to satisfy the borrow
        let spikes: Vec<bool> = currents01.iter().map(|&c| c > 0.5).collect();
        self.step_spikes(&spikes)
    }

    /// One batched timestep over the sessions selected by `active`
    /// (`active.len() == batch`). `input_spikes` is `n_in × batch`, laid
    /// out `[neuron][session]` like all batched state; entries of
    /// inactive sessions are ignored. Inactive sessions' membranes,
    /// traces and weights do not advance — a controller session only
    /// moves when its client submitted an observation this tick.
    ///
    /// Per-session arithmetic and operation order are identical to
    /// [`SnnNetwork::step_spikes`], so a batched session is bit-equivalent
    /// to a lone single-session network fed the same spike history (this
    /// is pinned by the `batched_matches_sequential_singles` test).
    ///
    /// Returns the full `n_out × batch` output spike buffer; inactive
    /// sessions' entries hold their previous values.
    pub fn step_spikes_masked(&mut self, input_spikes: &[bool], active: &[bool]) -> &[bool] {
        let b = self.batch;
        assert_eq!(input_spikes.len(), self.cfg.n_in * b);
        assert_eq!(active.len(), b);
        self.in_spikes.copy_from_slice(input_spikes);
        let shared = self.weights_shared();

        // --- L1 forward ---------------------------------------------------
        matvec_spikes_batch(
            &self.w1,
            shared,
            &self.in_spikes,
            self.cfg.n_in,
            self.cfg.n_hidden,
            b,
            active,
            &mut self.cur_hidden,
        );
        self.hidden.step_masked(&self.cur_hidden, active);

        // --- L2 forward ---------------------------------------------------
        matvec_spikes_batch(
            &self.w2,
            shared,
            &self.hidden.spikes,
            self.cfg.n_hidden,
            self.cfg.n_out,
            b,
            active,
            &mut self.cur_out,
        );
        self.output.step_masked(&self.cur_out, active);

        // --- Trace updates ------------------------------------------------
        self.trace_in.update_masked(&self.in_spikes, active);
        self.trace_hidden.update_masked(&self.hidden.spikes, active);
        self.trace_out.update_masked(&self.output.spikes, active);

        // --- Plasticity (per-session weights, shared θ) -------------------
        if let Mode::Plastic(rule) = &self.mode {
            apply_update_batch(
                &rule.l1,
                &self.cfg.plasticity,
                b,
                active,
                &mut self.w1,
                &self.trace_in.values,
                &self.trace_hidden.values,
            );
            apply_update_batch(
                &rule.l2,
                &self.cfg.plasticity,
                b,
                active,
                &mut self.w2,
                &self.trace_hidden.values,
                &self.trace_out.values,
            );
        }

        self.steps += 1;
        &self.output.spikes
    }

    /// Output trace snapshot as f32 (decoder input). For batched
    /// instances this is the full `[neuron][session]` buffer; use
    /// [`SnnNetwork::output_traces_f32_session`] for one session.
    pub fn output_traces_f32(&self) -> Vec<f32> {
        self.trace_out.values.iter().map(|v| v.to_f32()).collect()
    }

    /// One session's output-trace snapshot as f32 (decoder input).
    pub fn output_traces_f32_session(&self, session: usize) -> Vec<f32> {
        assert!(session < self.batch, "session out of range");
        (0..self.cfg.n_out)
            .map(|o| self.trace_out.values[o * self.batch + session].to_f32())
            .collect()
    }

    /// L∞ norm of the weight matrices (stability diagnostics).
    pub fn weight_linf(&self) -> f32 {
        self.w1
            .iter()
            .chain(self.w2.iter())
            .map(|w| w.to_f32().abs())
            .fold(0.0, f32::max)
    }

    /// Mean absolute weight (activity diagnostics).
    pub fn weight_mean_abs(&self) -> f32 {
        let total: f32 = self
            .w1
            .iter()
            .chain(self.w2.iter())
            .map(|w| w.to_f32().abs())
            .sum();
        total / (self.w1.len() + self.w2.len()) as f32
    }
}

/// Spike-driven matvec: `out[i] = Σ_j w[j][i] · s_j`. Because spikes are
/// binary this is a gather-accumulate over active rows only — the same
/// event-driven skip the FPGA's psum-stationary dataflow exploits (§III-B:
/// spikes "gate downstream logic").
pub fn matvec_spikes<S: Scalar>(w: &[S], spikes: &[bool], n_post: usize, out: &mut [S]) {
    assert_eq!(out.len(), n_post);
    assert_eq!(w.len(), spikes.len() * n_post);
    for o in out.iter_mut() {
        *o = S::ZERO;
    }
    for (j, &s) in spikes.iter().enumerate() {
        if !s {
            continue;
        }
        let row = &w[j * n_post..(j + 1) * n_post];
        for (o, &wv) in out.iter_mut().zip(row) {
            *o = o.add(wv);
        }
    }
}

/// Batched spike-driven matvec over `batch` independent sessions.
///
/// `spikes` is `n_pre × batch` (`[neuron][session]`), `out` is
/// `n_post × batch`. With `shared_w` the weight matrix is the plain
/// `n_pre × n_post` row-major layout used by fixed-weight deployments;
/// otherwise it is `n_pre × n_post × batch` (`[synapse][session]`).
/// Inactive sessions' outputs are zeroed but receive no accumulation.
/// The event-driven skip operates per (presynaptic neuron, session):
/// silent sessions of a row cost nothing, mirroring the spike gating of
/// the hardware dataflow.
#[allow(clippy::too_many_arguments)]
pub fn matvec_spikes_batch<S: Scalar>(
    w: &[S],
    shared_w: bool,
    spikes: &[bool],
    n_pre: usize,
    n_post: usize,
    batch: usize,
    active: &[bool],
    out: &mut [S],
) {
    assert_eq!(out.len(), n_post * batch);
    assert_eq!(spikes.len(), n_pre * batch);
    assert_eq!(active.len(), batch);
    let expect_w = if shared_w {
        n_pre * n_post
    } else {
        n_pre * n_post * batch
    };
    assert_eq!(w.len(), expect_w);
    for o in out.iter_mut() {
        *o = S::ZERO;
    }
    for j in 0..n_pre {
        let srow = &spikes[j * batch..(j + 1) * batch];
        // Event-driven skip: rows silent in every active session are free.
        if !srow.iter().zip(active).any(|(&s, &a)| s && a) {
            continue;
        }
        for i in 0..n_post {
            let orow = &mut out[i * batch..(i + 1) * batch];
            if shared_w {
                let wv = w[j * n_post + i];
                for b in 0..batch {
                    if active[b] && srow[b] {
                        orow[b] = orow[b].add(wv);
                    }
                }
            } else {
                let wrow = &w[(j * n_post + i) * batch..(j * n_post + i + 1) * batch];
                for b in 0..batch {
                    if active[b] && srow[b] {
                        orow[b] = orow[b].add(wrow[b]);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fp16::F16;
    use crate::util::rng::Pcg64;

    #[test]
    fn zero_weights_silent_without_rule() {
        let cfg = SnnConfig::tiny();
        let mut net = SnnNetwork::<f32>::new(cfg.clone(), Mode::Fixed);
        let spikes = vec![true; cfg.n_in];
        for _ in 0..10 {
            let out = net.step_spikes(&spikes);
            assert!(out.iter().all(|&s| !s));
        }
    }

    #[test]
    fn presynaptic_rule_bootstraps_from_zero() {
        // β > 0 on L1 grows weights from input activity alone, eventually
        // driving hidden spikes — the bootstrapping path Phase 2 relies on.
        let cfg = SnnConfig::tiny();
        let mut rule = NetworkRule::zeros(&cfg);
        for s in 0..cfg.l1_synapses() {
            rule.l1.theta[s * 4 + 1] = 0.5; // β
        }
        for s in 0..cfg.l2_synapses() {
            rule.l2.theta[s * 4 + 1] = 0.5;
        }
        let mut net = SnnNetwork::<f32>::new(cfg.clone(), Mode::Plastic(rule));
        let spikes = vec![true; cfg.n_in];
        let mut hidden_fired = false;
        let mut out_fired = false;
        for _ in 0..100 {
            net.step_spikes(&spikes);
            hidden_fired |= net.hidden.spikes.iter().any(|&s| s);
            out_fired |= net.output.spikes.iter().any(|&s| s);
        }
        assert!(hidden_fired, "hidden layer never fired");
        assert!(out_fired, "output layer never fired");
        assert!(net.weight_mean_abs() > 0.0);
    }

    #[test]
    fn delta_decay_keeps_weights_bounded() {
        let cfg = SnnConfig::tiny();
        let mut rule = NetworkRule::zeros(&cfg);
        for s in 0..cfg.l1_synapses() {
            rule.l1.theta[s * 4 + 1] = 1.0; // strong growth
            rule.l1.theta[s * 4 + 3] = -0.2; // regularization
        }
        let mut net = SnnNetwork::<f32>::new(cfg.clone(), Mode::Plastic(rule));
        let spikes = vec![true; cfg.n_in];
        for _ in 0..500 {
            net.step_spikes(&spikes);
        }
        assert!(net.weight_linf() <= net.cfg.plasticity.w_clip + 1e-6);
        assert!(net.weight_linf().is_finite());
    }

    #[test]
    fn reset_zeroes_plastic_weights_but_keeps_fixed() {
        let cfg = SnnConfig::tiny();
        let mut rng = Pcg64::new(5, 0);

        let mut fixed = SnnNetwork::<f32>::new(cfg.clone(), Mode::Fixed);
        let mut flat = vec![0.0f32; cfg.n_weights()];
        rng.fill_normal_f32(&mut flat, 1.0);
        fixed.load_weights(&flat);
        fixed.reset();
        assert!(fixed.weight_mean_abs() > 0.0, "fixed weights must survive reset");

        let rule = NetworkRule::zeros(&cfg);
        let mut plastic = SnnNetwork::<f32>::new(cfg.clone(), Mode::Plastic(rule));
        plastic.w1[0] = 1.0;
        plastic.reset();
        assert_eq!(plastic.w1[0], 0.0);
    }

    #[test]
    fn genome_round_trip() {
        let cfg = SnnConfig::tiny();
        let mut rng = Pcg64::new(6, 0);
        let mut flat = vec![0.0f32; cfg.n_rule_params()];
        rng.fill_normal_f32(&mut flat, 0.3);
        let rule = NetworkRule::from_flat(&cfg, &flat);
        assert_eq!(rule.to_flat(), flat);
    }

    #[test]
    fn matvec_matches_dense() {
        let mut rng = Pcg64::new(7, 0);
        let (n_pre, n_post) = (13, 9);
        let mut w = vec![0.0f32; n_pre * n_post];
        rng.fill_normal_f32(&mut w, 1.0);
        let spikes: Vec<bool> = (0..n_pre).map(|_| rng.bernoulli(0.4)).collect();
        let mut out = vec![0.0f32; n_post];
        matvec_spikes(&w, &spikes, n_post, &mut out);
        for i in 0..n_post {
            let mut expect = 0.0;
            for j in 0..n_pre {
                if spikes[j] {
                    expect += w[j * n_post + i];
                }
            }
            assert!((out[i] - expect).abs() < 1e-5);
        }
    }

    #[test]
    fn f16_network_tracks_f32_network() {
        let cfg = SnnConfig::tiny();
        let mut rng = Pcg64::new(8, 0);
        let mut flat = vec![0.0f32; cfg.n_rule_params()];
        rng.fill_normal_f32(&mut flat, 0.2);
        let rule = NetworkRule::from_flat(&cfg, &flat);

        let mut a = SnnNetwork::<f32>::new(cfg.clone(), Mode::Plastic(rule.clone()));
        let mut b = SnnNetwork::<F16>::new(cfg.clone(), Mode::Plastic(rule));
        let mut input_rng = Pcg64::new(9, 0);
        let mut spike_agreement = 0usize;
        let mut total = 0usize;
        for _ in 0..60 {
            let spikes: Vec<bool> = (0..cfg.n_in).map(|_| input_rng.bernoulli(0.5)).collect();
            let oa: Vec<bool> = a.step_spikes(&spikes).to_vec();
            let ob: Vec<bool> = b.step_spikes(&spikes).to_vec();
            spike_agreement += oa.iter().zip(&ob).filter(|(x, y)| x == y).count();
            total += oa.len();
        }
        // FP16 quantization may flip borderline spikes, but behaviour
        // must stay closely aligned (paper argues FP16 suffices).
        let agreement = spike_agreement as f64 / total as f64;
        assert!(agreement > 0.9, "spike agreement only {agreement}");
    }

    #[test]
    fn batched_matches_sequential_singles() {
        // B sessions stepped through one batched plastic network must be
        // bit-identical to B independent single-session networks fed the
        // same per-session spike streams — the correctness contract the
        // batching server relies on.
        let cfg = SnnConfig::tiny();
        let batch = 4;
        let mut rng = Pcg64::new(21, 0);
        let mut flat = vec![0.0f32; cfg.n_rule_params()];
        rng.fill_normal_f32(&mut flat, 0.25);
        let rule = NetworkRule::from_flat(&cfg, &flat);

        let mut batched =
            SnnNetwork::<f32>::new_batched(cfg.clone(), Mode::Plastic(rule.clone()), batch);
        let mut singles: Vec<SnnNetwork<f32>> = (0..batch)
            .map(|_| SnnNetwork::new(cfg.clone(), Mode::Plastic(rule.clone())))
            .collect();

        let active = vec![true; batch];
        let mut input_rng = Pcg64::new(22, 0);
        for _ in 0..40 {
            // independent spike stream per session, [neuron][session]
            let mut inmat = vec![false; cfg.n_in * batch];
            for b in 0..batch {
                for j in 0..cfg.n_in {
                    inmat[j * batch + b] = input_rng.bernoulli(0.4 + 0.1 * b as f64);
                }
            }
            batched.step_spikes_masked(&inmat, &active);
            for (b, single) in singles.iter_mut().enumerate() {
                let spikes: Vec<bool> = (0..cfg.n_in).map(|j| inmat[j * batch + b]).collect();
                single.step_spikes(&spikes);
                for o in 0..cfg.n_out {
                    assert_eq!(
                        batched.output.spikes[o * batch + b],
                        single.output.spikes[o],
                        "output spike mismatch session {b} neuron {o}"
                    );
                }
            }
        }
        // weights bit-exact per session after 40 plastic steps
        for (b, single) in singles.iter().enumerate() {
            for s in 0..cfg.l1_synapses() {
                assert_eq!(batched.w1[s * batch + b], single.w1[s], "w1 s{b} syn{s}");
            }
            for s in 0..cfg.l2_synapses() {
                assert_eq!(batched.w2[s * batch + b], single.w2[s], "w2 s{b} syn{s}");
            }
            assert_eq!(
                batched.output_traces_f32_session(b),
                single.output_traces_f32()
            );
        }
    }

    #[test]
    fn masked_sessions_do_not_advance() {
        let cfg = SnnConfig::tiny();
        let batch = 3;
        let mut rng = Pcg64::new(23, 0);
        let mut flat = vec![0.0f32; cfg.n_rule_params()];
        rng.fill_normal_f32(&mut flat, 0.3);
        let rule = NetworkRule::from_flat(&cfg, &flat);
        let mut net = SnnNetwork::<f32>::new_batched(cfg.clone(), Mode::Plastic(rule), batch);

        let mut inmat = vec![true; cfg.n_in * batch];
        // session 1 inactive: even with garbage input bits set, its state
        // must stay exactly zero
        for j in 0..cfg.n_in {
            inmat[j * batch + 1] = true;
        }
        let active = [true, false, true];
        for _ in 0..30 {
            net.step_spikes_masked(&inmat, &active);
        }
        for s in 0..cfg.l1_synapses() {
            assert_eq!(net.w1[s * batch + 1], 0.0, "masked session weight moved");
        }
        for o in 0..cfg.n_out {
            assert_eq!(net.trace_out.values[o * batch + 1], 0.0);
        }
        // active sessions did move
        assert!(net.w1.iter().any(|&w| w != 0.0));

        // per-session reset clears only that column
        net.reset_session(0);
        for s in 0..cfg.l1_synapses().min(64) {
            assert_eq!(net.w1[s * batch], 0.0);
        }
        assert!(
            (0..cfg.l1_synapses()).any(|s| net.w1[s * batch + 2] != 0.0),
            "session 2 must survive session 0's reset"
        );
    }

    #[test]
    fn batched_fixed_mode_shares_one_weight_copy() {
        let cfg = SnnConfig::tiny();
        let mut net = SnnNetwork::<f32>::new_batched(cfg.clone(), Mode::Fixed, 8);
        assert_eq!(net.w1.len(), cfg.l1_synapses(), "fixed weights must not replicate");
        let mut rng = Pcg64::new(24, 0);
        let mut flat = vec![0.0f32; cfg.n_weights()];
        rng.fill_normal_f32(&mut flat, 1.0);
        net.load_weights(&flat);
        let active = vec![true; 8];
        let inmat = vec![true; cfg.n_in * 8];
        net.step_spikes_masked(&inmat, &active);
        // identical inputs + shared weights → identical outputs per session
        for o in 0..cfg.n_out {
            let first = net.output.spikes[o * 8];
            for b in 1..8 {
                assert_eq!(net.output.spikes[o * 8 + b], first);
            }
        }
    }

    #[test]
    fn steady_state_step_is_allocation_free_observable() {
        // Proxy check: repeated stepping does not grow weight/trace
        // buffer lengths (we can't intercept the allocator, but we pin
        // the state sizes the hot loop touches).
        let cfg = SnnConfig::tiny();
        let rule = NetworkRule::zeros(&cfg);
        let mut net = SnnNetwork::<f32>::new(cfg.clone(), Mode::Plastic(rule));
        let spikes = vec![true; cfg.n_in];
        let w1_cap = net.w1.capacity();
        for _ in 0..100 {
            net.step_spikes(&spikes);
        }
        assert_eq!(net.w1.capacity(), w1_cap);
        assert_eq!(net.steps, 100);
    }
}
