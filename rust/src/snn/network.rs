//! The three-layer fully connected SNN controller (§IV-A: input →
//! 128 hidden → output for control; 784 → 1024 → 10 for MNIST).
//!
//! "Three-layer" counts neuron populations; there are **two synaptic
//! layers** — exactly the L1/L2 pair the hardware pipeline overlaps
//! (§III-C). The network is purely feed-forward, stepped once per control
//! tick through the **fused packed pipeline** (DESIGN.md §Hot-Path):
//!
//! 1. input trace decay/accumulate (packed input spike words)
//! 2. L1: event-driven psum accumulation over the set bits of the input
//!    spike words, then fused hidden LIF + trace pass
//! 3. L2: event-driven accumulation over hidden spike words, then fused
//!    output LIF + trace pass
//! 4. (plastic mode) word-masked four-term rule update of W₁ and W₂
//!
//! Spikes are carried end-to-end as bit-packed `u64` session words
//! ([`SpikeWords`]); the dense boolean formulation survives as the
//! reference oracle in [`crate::snn::reference`] and the equivalence
//! suite pins the packed path bit-exact against it.
//!
//! Weights start at **zero** in plastic mode (§II-B Phase 2): all task
//! competence emerges online from the learned rule.

use std::sync::Arc;

use super::lif::LifLayer;
use super::numeric::Scalar;
use super::plasticity::{apply_update_batch, PlasticityConfig, RuleParams};
use super::spike::{self, grow_lanes, SpikeWords, LANES};
use super::trace::TraceVector;

/// Static architecture + dynamics constants.
#[derive(Clone, Debug)]
pub struct SnnConfig {
    /// Input population size (encoder neurons).
    pub n_in: usize,
    /// Hidden population size (paper: 128 for control, 1024 for MNIST).
    pub n_hidden: usize,
    /// Output population size (decoder neurons).
    pub n_out: usize,
    /// Trace decay λ (default 0.5 — a shift in hardware).
    pub lambda: f32,
    /// LIF threshold.
    pub v_th: f32,
    /// Input current gain applied to encoded observations.
    pub input_gain: f32,
    /// Online-update hyper-parameters (η scale and weight clip).
    pub plasticity: PlasticityConfig,
}

impl SnnConfig {
    /// Control-geometry config: `n_in → 128 → n_out` with paper defaults.
    pub fn control(n_in: usize, n_out: usize) -> Self {
        SnnConfig {
            n_in,
            n_hidden: 128,
            n_out,
            lambda: 0.5,
            v_th: 1.0,
            input_gain: 2.0,
            plasticity: PlasticityConfig::default(),
        }
    }

    /// Table-II MNIST geometry: 784 → 1024 → 10.
    pub fn mnist() -> Self {
        SnnConfig {
            n_in: 784,
            n_hidden: 1024,
            n_out: 10,
            lambda: 0.5,
            v_th: 1.0,
            input_gain: 2.0,
            plasticity: PlasticityConfig::default(),
        }
    }

    /// Small architecture for tests and the FPGA unit benches.
    pub fn tiny() -> Self {
        SnnConfig {
            n_in: 8,
            n_hidden: 16,
            n_out: 4,
            lambda: 0.5,
            v_th: 1.0,
            input_gain: 2.0,
            plasticity: PlasticityConfig::default(),
        }
    }

    /// Synapse count of the input → hidden layer.
    pub fn l1_synapses(&self) -> usize {
        self.n_in * self.n_hidden
    }

    /// Synapse count of the hidden → output layer.
    pub fn l2_synapses(&self) -> usize {
        self.n_hidden * self.n_out
    }

    /// Total θ dimension for the ES genome (both layers).
    pub fn n_rule_params(&self) -> usize {
        4 * (self.l1_synapses() + self.l2_synapses())
    }

    /// Total weight count (for the weight-trained baseline genome).
    pub fn n_weights(&self) -> usize {
        self.l1_synapses() + self.l2_synapses()
    }
}

/// The frozen learning rule for both synaptic layers (Phase-1 output).
#[derive(Clone, Debug)]
pub struct NetworkRule {
    /// Rule coefficients for the input → hidden synapses.
    pub l1: RuleParams,
    /// Rule coefficients for the hidden → output synapses.
    pub l2: RuleParams,
}

impl NetworkRule {
    /// All-zero rule (no plasticity) sized for `cfg`.
    pub fn zeros(cfg: &SnnConfig) -> Self {
        NetworkRule {
            l1: RuleParams::zeros(cfg.n_in, cfg.n_hidden),
            l2: RuleParams::zeros(cfg.n_hidden, cfg.n_out),
        }
    }

    /// Load from a flat ES genome: `[θ_L1 ‖ θ_L2]`.
    pub fn from_flat(cfg: &SnnConfig, flat: &[f32]) -> Self {
        assert_eq!(flat.len(), cfg.n_rule_params(), "genome length mismatch");
        let mut rule = Self::zeros(cfg);
        let split = 4 * cfg.l1_synapses();
        rule.l1.load_flat(&flat[..split]);
        rule.l2.load_flat(&flat[split..]);
        rule
    }

    /// Serialize back to the flat ES genome layout `[θ_L1 ‖ θ_L2]`.
    pub fn to_flat(&self) -> Vec<f32> {
        let mut v = Vec::with_capacity(self.l1.theta.len() + self.l2.theta.len());
        v.extend_from_slice(&self.l1.theta);
        v.extend_from_slice(&self.l2.theta);
        v
    }
}

/// How synaptic weights evolve during an episode.
///
/// The plastic payload is an [`Arc`] so the frozen rule θ — by far the
/// largest parameter array (4 f32 per synapse) — is stored **once per
/// process** and shared by every clone: the sharded stepper's per-core
/// networks ([`crate::snn::ShardedNetwork`]) all point at the same
/// allocation instead of carrying per-shard copies (`Mode::clone` is an
/// Arc refcount bump, ~free). `NetworkRule: From` into
/// `Arc<NetworkRule>` is provided by std, so construction sites write
/// `Mode::Plastic(rule.into())`.
#[derive(Clone, Debug)]
pub enum Mode {
    /// Phase-2 FireFly-P: zero-initialized weights + online rule updates
    /// under a process-wide shared frozen rule θ.
    Plastic(Arc<NetworkRule>),
    /// Baseline: fixed, directly trained weights; no online updates.
    Fixed,
}

impl Mode {
    /// The shared frozen rule, if this mode is plastic (diagnostics and
    /// the shard θ-sharing tests).
    pub fn rule(&self) -> Option<&Arc<NetworkRule>> {
        match self {
            Mode::Plastic(rule) => Some(rule),
            Mode::Fixed => None,
        }
    }
}

/// Full mutable network state, generic over the arithmetic domain.
///
/// Carries a structure-of-arrays **batch dimension** for multi-session
/// serving (DESIGN.md §Batched-Serving). One network instance holds
/// `batch` independent controller sessions that share the static parts —
/// the config, and in plastic mode the frozen rule θ (by far the largest
/// array: 4 f32 per synapse) — while membranes, traces, and (in plastic
/// mode) the evolving weights are per-session, interleaved
/// `[element][session]`. Spikes are bit-packed `u64` session words
/// ([`SpikeWords`], DESIGN.md §Hot-Path). `batch == 1` (the
/// [`SnnNetwork::new`] default) keeps the historical single-session
/// scalar layouts.
///
/// In [`Mode::Fixed`] the weights never change, so they are stored once
/// (`n_in × n_hidden`, no batch dimension) and shared by every session.
#[derive(Clone, Debug)]
pub struct SnnNetwork<S: Scalar> {
    /// Static architecture and dynamics constants.
    pub cfg: SnnConfig,
    /// Plastic (shared rule θ, per-session weights) or fixed weights.
    pub mode: Mode,
    /// L1 weights. Plastic: `n_in × n_hidden × batch`, laid out
    /// `[synapse][session]`. Fixed: `n_in × n_hidden` row-major, shared
    /// across sessions.
    pub w1: Vec<S>,
    /// L2 weights; same layout rules as `w1` with `n_hidden × n_out`.
    pub w2: Vec<S>,
    /// Hidden LIF population (batched, packed spikes).
    pub hidden: LifLayer<S>,
    /// Output LIF population (batched, packed spikes).
    pub output: LifLayer<S>,
    /// Input-population spike traces (batched).
    pub trace_in: TraceVector<S>,
    /// Hidden-population spike traces (batched).
    pub trace_hidden: TraceVector<S>,
    /// Output-population spike traces (batched).
    pub trace_out: TraceVector<S>,
    /// Number of independent sessions this instance multiplexes.
    pub batch: usize,
    /// Input spike staging, bit-packed (set by the step entry points or
    /// directly via [`SnnNetwork::input_mut`]).
    in_spikes: SpikeWords,
    /// Packed active-session mask scratch.
    active_words: Vec<u64>,
    /// Scratch current buffers (allocation-free steady state).
    cur_hidden: Vec<S>,
    cur_out: Vec<S>,
    /// Dense staging for the `&[bool]` return of the single-session step
    /// entry points.
    out_bools: Vec<bool>,
    /// Timesteps executed (batched steps count once).
    pub steps: u64,
    /// Presynaptic rows visited by the most recent plastic step's rule
    /// sweep, per synaptic layer `[L1, L2]`. Equal to `[n_in, n_hidden]`
    /// unless event-driven gating
    /// ([`PlasticityConfig::presyn_gate`]) skipped silent rows.
    pub plasticity_rows_visited: [usize; 2],
    /// Runtime plasticity gate (overload shedding): when `false`, a
    /// plastic-mode step skips the rule sweep entirely — the per-session
    /// weights freeze at their current values — while the forward pass,
    /// membranes and traces step unchanged. Ignored in [`Mode::Fixed`].
    plasticity_enabled: bool,
}

impl<S: Scalar> SnnNetwork<S> {
    /// Single-session network (the historical constructor).
    pub fn new(cfg: SnnConfig, mode: Mode) -> Self {
        Self::new_batched(cfg, mode, 1)
    }

    /// Network multiplexing `batch` independent sessions in
    /// structure-of-arrays layout. All sessions share `cfg` and the rule
    /// θ; each has its own membrane/trace (and, in plastic mode, weight)
    /// state.
    pub fn new_batched(cfg: SnnConfig, mode: Mode, batch: usize) -> Self {
        assert!(batch >= 1, "batch must be >= 1");
        let (n_in, n_h, n_o) = (cfg.n_in, cfg.n_hidden, cfg.n_out);
        let lambda = cfg.lambda;
        let v_th = cfg.v_th;
        // Fixed weights are session-invariant: store one copy.
        let wb = if matches!(mode, Mode::Plastic(_)) { batch } else { 1 };
        // Event-driven plasticity keys the input traces lazy: decay is
        // deferred per lane and silent (all-zero) presynaptic rows cost
        // nothing per tick (DESIGN.md §Hot-Path). Hidden/output traces
        // stay eager — their update is fused into the LIF sweep that
        // must touch every membrane anyway, and they double as post
        // traces, which every visited row's update reads.
        let trace_in = if cfg.plasticity.presyn_gate {
            TraceVector::batched_lazy(n_in, batch, lambda)
        } else {
            TraceVector::batched(n_in, batch, lambda)
        };
        SnnNetwork {
            w1: vec![S::ZERO; n_in * n_h * wb],
            w2: vec![S::ZERO; n_h * n_o * wb],
            hidden: LifLayer::batched(n_h, batch, v_th),
            output: LifLayer::batched(n_o, batch, v_th),
            trace_in,
            trace_hidden: TraceVector::batched(n_h, batch, lambda),
            trace_out: TraceVector::batched(n_o, batch, lambda),
            in_spikes: SpikeWords::new(n_in, batch),
            active_words: vec![0; spike::words_for(batch)],
            cur_hidden: vec![S::ZERO; n_h * batch],
            cur_out: vec![S::ZERO; n_o * batch],
            out_bools: vec![false; n_o * batch],
            steps: 0,
            plasticity_rows_visited: [0, 0],
            plasticity_enabled: true,
            batch,
            cfg,
            mode,
        }
    }

    /// Toggle the runtime plasticity gate (overload shedding, DESIGN.md
    /// §Durability-and-Faults): `false` freezes the per-session weights
    /// at their current values — the plastic rule sweep is skipped
    /// entirely — while the forward pass, membranes and traces step
    /// unchanged; `true` (the default) resumes online updates from the
    /// frozen weights. The shared rule θ is read-only either way, so
    /// toggling can never corrupt it. No effect in [`Mode::Fixed`].
    pub fn set_plasticity_enabled(&mut self, on: bool) {
        self.plasticity_enabled = on;
    }

    /// Whether the runtime plasticity gate is open (see
    /// [`SnnNetwork::set_plasticity_enabled`]).
    pub fn plasticity_enabled(&self) -> bool {
        self.plasticity_enabled
    }

    /// Whether `w1`/`w2` are stored once and shared by every session
    /// (fixed mode) rather than per-session (plastic mode).
    #[inline]
    pub fn weights_shared(&self) -> bool {
        matches!(self.mode, Mode::Fixed)
    }

    /// Install fixed weights (baseline mode) from flat `[W1 ‖ W2]`.
    pub fn load_weights(&mut self, flat: &[f32]) {
        assert_eq!(flat.len(), self.cfg.n_weights(), "weight vector mismatch");
        let split = self.cfg.l1_synapses();
        for (w, &x) in self.w1.iter_mut().zip(&flat[..split]) {
            *w = S::from_f32(x);
        }
        for (w, &x) in self.w2.iter_mut().zip(&flat[split..]) {
            *w = S::from_f32(x);
        }
    }

    /// Reset all dynamic state of **every** session (weights too, in
    /// plastic mode — Phase 2 starts every deployment from w = 0).
    pub fn reset(&mut self) {
        if matches!(self.mode, Mode::Plastic(_)) {
            for w in self.w1.iter_mut() {
                *w = S::ZERO;
            }
            for w in self.w2.iter_mut() {
                *w = S::ZERO;
            }
        }
        self.hidden.reset();
        self.output.reset();
        self.trace_in.reset();
        self.trace_hidden.reset();
        self.trace_out.reset();
        self.in_spikes.clear();
        self.steps = 0;
    }

    /// Reset one session's dynamic state (its weight column too, in
    /// plastic mode), leaving every other session untouched.
    pub fn reset_session(&mut self, session: usize) {
        assert!(session < self.batch, "session out of range");
        if matches!(self.mode, Mode::Plastic(_)) {
            let b = self.batch;
            for s in 0..self.cfg.l1_synapses() {
                self.w1[s * b + session] = S::ZERO;
            }
            for s in 0..self.cfg.l2_synapses() {
                self.w2[s * b + session] = S::ZERO;
            }
        }
        self.hidden.reset_session(session);
        self.output.reset_session(session);
        self.trace_in.reset_session(session);
        self.trace_hidden.reset_session(session);
        self.trace_out.reset_session(session);
        self.in_spikes.clear_session(session);
    }

    /// Grow the session dimension to `new_batch` **without resetting
    /// existing sessions**: membranes, traces, spike words and (in
    /// plastic mode) the per-session weight lanes of live sessions are
    /// preserved; new sessions start from the zero state. Growing is the
    /// only direction — shrink by resetting sessions instead.
    pub fn grow_batch(&mut self, new_batch: usize) {
        assert!(new_batch >= self.batch, "batch can only grow");
        if new_batch == self.batch {
            return;
        }
        if matches!(self.mode, Mode::Plastic(_)) {
            self.w1 = grow_lanes(&self.w1, self.batch, new_batch, S::ZERO);
            self.w2 = grow_lanes(&self.w2, self.batch, new_batch, S::ZERO);
        }
        self.hidden.grow_batch(new_batch);
        self.output.grow_batch(new_batch);
        self.trace_in.grow_batch(new_batch);
        self.trace_hidden.grow_batch(new_batch);
        self.trace_out.grow_batch(new_batch);
        self.in_spikes.grow_batch(new_batch);
        self.active_words = vec![0; spike::words_for(new_batch)];
        self.cur_hidden = vec![S::ZERO; self.cfg.n_hidden * new_batch];
        self.cur_out = vec![S::ZERO; self.cfg.n_out * new_batch];
        self.out_bools = vec![false; self.cfg.n_out * new_batch];
        self.batch = new_batch;
    }

    /// One network timestep driven by already-binary input spikes.
    /// Returns a reference to the output spike vector. Single-session
    /// instances only; batched instances use
    /// [`SnnNetwork::step_spikes_masked`] or the packed staging entry
    /// points ([`SnnNetwork::input_mut`] + [`SnnNetwork::step_staged`]).
    pub fn step_spikes(&mut self, input_spikes: &[bool]) -> &[bool] {
        assert_eq!(self.batch, 1, "batched networks step via step_spikes_masked");
        assert_eq!(input_spikes.len(), self.cfg.n_in);
        self.in_spikes.fill_from_bools(input_spikes);
        self.active_words[0] = 1;
        self.step_staged_words();
        self.refresh_out_bools();
        &self.out_bools
    }

    /// One timestep driven by analog input currents: each input neuron is
    /// a probabilistic/threshold encoder handled upstream; here values in
    /// [0, 1] are compared against a fixed 0.5 threshold — the
    /// deterministic current encoder used by the control stack (see
    /// `encoding::CurrentEncoder` for richer schemes). Thresholding
    /// writes straight into the packed staging words — no intermediate
    /// boolean buffer is allocated.
    pub fn step_currents(&mut self, currents01: &[f32]) -> &[bool] {
        assert_eq!(self.batch, 1, "batched networks step via step_spikes_masked");
        assert_eq!(currents01.len(), self.cfg.n_in);
        for (j, &c) in currents01.iter().enumerate() {
            self.in_spikes.set(j, 0, c > 0.5);
        }
        self.active_words[0] = 1;
        self.step_staged_words();
        self.refresh_out_bools();
        &self.out_bools
    }

    /// One batched timestep over the sessions selected by `active`
    /// (`active.len() == batch`). `input_spikes` is `n_in × batch`, laid
    /// out `[neuron][session]` like all batched state; entries of
    /// inactive sessions are ignored. Inactive sessions' membranes,
    /// traces and weights do not advance — a controller session only
    /// moves when its client submitted an observation this tick.
    ///
    /// Per-session arithmetic and operation order are identical to
    /// [`SnnNetwork::step_spikes`], so a batched session is bit-equivalent
    /// to a lone single-session network fed the same spike history (this
    /// is pinned by the equivalence suite against the dense scalar
    /// reference in [`crate::snn::reference`]).
    ///
    /// Returns the packed `n_out × batch` output spike words; inactive
    /// sessions' bits hold their previous values.
    pub fn step_spikes_masked(&mut self, input_spikes: &[bool], active: &[bool]) -> &SpikeWords {
        let b = self.batch;
        assert_eq!(input_spikes.len(), self.cfg.n_in * b);
        assert_eq!(active.len(), b);
        self.in_spikes.fill_from_bools(input_spikes);
        spike::pack_mask_into(active, &mut self.active_words);
        self.step_staged_words();
        &self.output.spikes
    }

    /// Mutable access to the packed input staging words, so callers on
    /// the serving hot path (the native backend) can scatter request
    /// spikes straight into packed form — no dense boolean matrix is
    /// materialized. Clear before writing; then advance with
    /// [`SnnNetwork::step_staged`].
    #[inline]
    pub fn input_mut(&mut self) -> &mut SpikeWords {
        &mut self.in_spikes
    }

    /// Read-only view of the packed input staging words (serving
    /// snapshots capture them so a restored network re-encodes
    /// bit-identically).
    #[inline]
    pub fn input(&self) -> &SpikeWords {
        &self.in_spikes
    }

    /// Step using input spikes previously staged through
    /// [`SnnNetwork::input_mut`], advancing only the sessions flagged in
    /// `active`. Returns the packed output spike words.
    pub fn step_staged(&mut self, active: &[bool]) -> &SpikeWords {
        assert_eq!(active.len(), self.batch, "mask/batch mismatch");
        spike::pack_mask_into(active, &mut self.active_words);
        self.step_staged_words();
        &self.output.spikes
    }

    /// The fused packed step pipeline (DESIGN.md §Hot-Path). Consumes
    /// the staged `in_spikes` + `active_words` and performs, per layer,
    /// one event-driven accumulation followed by one fused LIF + trace
    /// pass, then the word-masked plasticity sweep. No allocation.
    fn step_staged_words(&mut self) {
        let b = self.batch;
        let shared = self.weights_shared();

        // Input traces first — independent of the forwards, and the
        // staging pass that produced `in_spikes` is still cache-hot.
        // Lazy mode (event-driven plasticity): advance the per-session
        // clocks, fold in this tick's spikes event-wise, then bring the
        // hot lanes current so the plasticity sweep below reads fully
        // materialized pre-traces (cold rows are exactly zero by
        // invariant). Bit-identical to the eager update.
        if self.trace_in.is_lazy() {
            self.trace_in.tick(&self.active_words);
            self.trace_in
                .record_spikes_packed(&self.in_spikes, &self.active_words);
            self.trace_in.materialize_hot();
        } else {
            self.trace_in.update_packed(&self.in_spikes, &self.active_words);
        }

        // --- L1: event-driven accumulate + fused hidden LIF/trace -----
        matvec_spikes_packed(
            &self.w1,
            shared,
            &self.in_spikes,
            self.cfg.n_hidden,
            b,
            &self.active_words,
            &mut self.cur_hidden,
        );
        self.hidden
            .step_trace_masked(&self.cur_hidden, &mut self.trace_hidden, &self.active_words);

        // --- L2: event-driven accumulate + fused output LIF/trace -----
        matvec_spikes_packed(
            &self.w2,
            shared,
            &self.hidden.spikes,
            self.cfg.n_out,
            b,
            &self.active_words,
            &mut self.cur_out,
        );
        self.output
            .step_trace_masked(&self.cur_out, &mut self.trace_out, &self.active_words);

        // --- Plasticity (per-session weights, shared θ, word mask) ----
        if let (Mode::Plastic(rule), true) = (&self.mode, self.plasticity_enabled) {
            // L1's pre-traces are the lazy input traces: their hot masks
            // (exact after the materialize_hot above) prefilter the gate
            // so fully-cold rows skip in one AND per word. L2's
            // pre-traces (hidden) are eager — no mask, value scan only.
            let hot1 = if self.trace_in.is_lazy() {
                Some(self.trace_in.hot_rows())
            } else {
                None
            };
            let v1 = apply_update_batch(
                &rule.l1,
                &self.cfg.plasticity,
                b,
                &self.active_words,
                hot1,
                &mut self.w1,
                &self.trace_in.values,
                &self.trace_hidden.values,
            );
            let v2 = apply_update_batch(
                &rule.l2,
                &self.cfg.plasticity,
                b,
                &self.active_words,
                None,
                &mut self.w2,
                &self.trace_hidden.values,
                &self.trace_out.values,
            );
            self.plasticity_rows_visited = [v1, v2];
        } else {
            // Gate closed (or fixed mode): no rows swept this tick.
            self.plasticity_rows_visited = [0, 0];
        }

        self.steps += 1;
    }

    /// Refresh the dense boolean staging of the output spikes (single-
    /// session convenience returns).
    fn refresh_out_bools(&mut self) {
        self.output.spikes.write_bools(&mut self.out_bools);
    }

    /// Output trace snapshot as f32 (decoder input). For batched
    /// instances this is the full `[neuron][session]` buffer; use
    /// [`SnnNetwork::output_traces_f32_session`] for one session.
    pub fn output_traces_f32(&self) -> Vec<f32> {
        self.trace_out.values.iter().map(|v| v.to_f32()).collect()
    }

    /// One session's output-trace snapshot as f32 (decoder input).
    pub fn output_traces_f32_session(&self, session: usize) -> Vec<f32> {
        assert!(session < self.batch, "session out of range");
        (0..self.cfg.n_out)
            .map(|o| self.trace_out.values[o * self.batch + session].to_f32())
            .collect()
    }

    /// L∞ norm of the weight matrices (stability diagnostics).
    pub fn weight_linf(&self) -> f32 {
        self.w1
            .iter()
            .chain(self.w2.iter())
            .map(|w| w.to_f32().abs())
            .fold(0.0, f32::max)
    }

    /// Mean absolute weight (activity diagnostics).
    pub fn weight_mean_abs(&self) -> f32 {
        let total: f32 = self
            .w1
            .iter()
            .chain(self.w2.iter())
            .map(|w| w.to_f32().abs())
            .sum();
        total / (self.w1.len() + self.w2.len()) as f32
    }
}

/// Packed event-driven spike matvec over `batch` independent sessions
/// (DESIGN.md §Hot-Path).
///
/// `spikes` carries the presynaptic population as bit-packed session
/// words; `out` is `n_post × batch` (`[neuron][session]`). With
/// `shared_w` the weight matrix is the plain `n_pre × n_post` row-major
/// layout used by fixed-weight deployments; otherwise it is
/// `n_pre × n_post × batch` (`[synapse][session]`).
///
/// The accumulation is **event-driven at (presynaptic neuron, session)
/// granularity**: each presynaptic row's spike word ANDs against the
/// active mask, a zero word skips in one compare, and a
/// `trailing_zeros` walk visits only the set bits — so the work scales
/// with the firing rate instead of `n_pre × n_post × batch`, mirroring
/// the spike gating of the hardware dataflow. Presynaptic rows are
/// visited in ascending order, so per-(postsynaptic, session)
/// accumulation order matches the dense reference exactly
/// (bit-for-bit).
///
/// All `out` entries are zeroed first; inactive sessions' outputs are
/// therefore zero but receive no accumulation.
///
/// This kernel is the *sparse gather* of the pipeline: its per-event
/// inner walk is strided by design (it scatters one session lane across
/// the postsynaptic rows), so the auto-vectorization contract
/// (DESIGN.md §Hot-Path) applies to the dense lane kernels
/// ([`crate::snn::LifLayer::step_trace_masked`],
/// [`crate::snn::plasticity::apply_update_batch`]) rather than here;
/// this function is `#[inline]` so the event loop fuses into the caller
/// and the `shared_w` flag constant-folds.
#[inline]
pub fn matvec_spikes_packed<S: Scalar>(
    w: &[S],
    shared_w: bool,
    spikes: &SpikeWords,
    n_post: usize,
    batch: usize,
    active_words: &[u64],
    out: &mut [S],
) {
    let n_pre = spikes.neurons();
    assert_eq!(out.len(), n_post * batch);
    assert_eq!(spikes.batch(), batch, "spike/batch mismatch");
    assert_eq!(active_words.len(), spikes.words_per_row(), "mask/batch mismatch");
    let expect_w = if shared_w {
        n_pre * n_post
    } else {
        n_pre * n_post * batch
    };
    assert_eq!(w.len(), expect_w);
    for o in out.iter_mut() {
        *o = S::ZERO;
    }
    for j in 0..n_pre {
        let row = spikes.row(j);
        // One weight-row slice per presynaptic neuron (hoisted out of
        // the per-event walk).
        let wrow = if shared_w {
            &w[j * n_post..(j + 1) * n_post]
        } else {
            &w[j * n_post * batch..(j + 1) * n_post * batch]
        };
        for (wi, &aw) in active_words.iter().enumerate() {
            let mut m = row[wi] & aw;
            // trailing_zeros walk: cost ∝ set bits, not lanes.
            while m != 0 {
                let lane = wi * LANES + m.trailing_zeros() as usize;
                m &= m - 1;
                if shared_w {
                    for (i, &wv) in wrow.iter().enumerate() {
                        out[i * batch + lane] = out[i * batch + lane].add(wv);
                    }
                } else {
                    for i in 0..n_post {
                        let idx = i * batch + lane;
                        out[idx] = out[idx].add(wrow[i * batch + lane]);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::reference::{matvec_spikes_batch, ReferenceNetwork};
    use crate::snn::spike::mask_words;
    use crate::util::fp16::F16;
    use crate::util::rng::Pcg64;

    #[test]
    fn zero_weights_silent_without_rule() {
        let cfg = SnnConfig::tiny();
        let mut net = SnnNetwork::<f32>::new(cfg.clone(), Mode::Fixed);
        let spikes = vec![true; cfg.n_in];
        for _ in 0..10 {
            let out = net.step_spikes(&spikes);
            assert!(out.iter().all(|&s| !s));
        }
    }

    #[test]
    fn presynaptic_rule_bootstraps_from_zero() {
        // β > 0 on L1 grows weights from input activity alone, eventually
        // driving hidden spikes — the bootstrapping path Phase 2 relies on.
        let cfg = SnnConfig::tiny();
        let mut rule = NetworkRule::zeros(&cfg);
        for s in 0..cfg.l1_synapses() {
            rule.l1.theta[s * 4 + 1] = 0.5; // β
        }
        for s in 0..cfg.l2_synapses() {
            rule.l2.theta[s * 4 + 1] = 0.5;
        }
        let mut net = SnnNetwork::<f32>::new(cfg.clone(), Mode::Plastic(rule.into()));
        let spikes = vec![true; cfg.n_in];
        let mut hidden_fired = false;
        let mut out_fired = false;
        for _ in 0..100 {
            net.step_spikes(&spikes);
            hidden_fired |= net.hidden.spikes.any();
            out_fired |= net.output.spikes.any();
        }
        assert!(hidden_fired, "hidden layer never fired");
        assert!(out_fired, "output layer never fired");
        assert!(net.weight_mean_abs() > 0.0);
    }

    #[test]
    fn delta_decay_keeps_weights_bounded() {
        let cfg = SnnConfig::tiny();
        let mut rule = NetworkRule::zeros(&cfg);
        for s in 0..cfg.l1_synapses() {
            rule.l1.theta[s * 4 + 1] = 1.0; // strong growth
            rule.l1.theta[s * 4 + 3] = -0.2; // regularization
        }
        let mut net = SnnNetwork::<f32>::new(cfg.clone(), Mode::Plastic(rule.into()));
        let spikes = vec![true; cfg.n_in];
        for _ in 0..500 {
            net.step_spikes(&spikes);
        }
        assert!(net.weight_linf() <= net.cfg.plasticity.w_clip + 1e-6);
        assert!(net.weight_linf().is_finite());
    }

    #[test]
    fn reset_zeroes_plastic_weights_but_keeps_fixed() {
        let cfg = SnnConfig::tiny();
        let mut rng = Pcg64::new(5, 0);

        let mut fixed = SnnNetwork::<f32>::new(cfg.clone(), Mode::Fixed);
        let mut flat = vec![0.0f32; cfg.n_weights()];
        rng.fill_normal_f32(&mut flat, 1.0);
        fixed.load_weights(&flat);
        fixed.reset();
        assert!(fixed.weight_mean_abs() > 0.0, "fixed weights must survive reset");

        let rule = NetworkRule::zeros(&cfg);
        let mut plastic = SnnNetwork::<f32>::new(cfg.clone(), Mode::Plastic(rule.into()));
        plastic.w1[0] = 1.0;
        plastic.reset();
        assert_eq!(plastic.w1[0], 0.0);
    }

    #[test]
    fn genome_round_trip() {
        let cfg = SnnConfig::tiny();
        let mut rng = Pcg64::new(6, 0);
        let mut flat = vec![0.0f32; cfg.n_rule_params()];
        rng.fill_normal_f32(&mut flat, 0.3);
        let rule = NetworkRule::from_flat(&cfg, &flat);
        assert_eq!(rule.to_flat(), flat);
    }

    #[test]
    fn packed_matvec_matches_dense_batched() {
        let mut rng = Pcg64::new(7, 0);
        let (n_pre, n_post) = (13, 9);
        for &batch in &[1usize, 3, 64, 67] {
            let mut w = vec![0.0f32; n_pre * n_post * batch];
            rng.fill_normal_f32(&mut w, 1.0);
            let dense: Vec<bool> = (0..n_pre * batch).map(|_| rng.bernoulli(0.3)).collect();
            let active: Vec<bool> = (0..batch).map(|_| rng.bernoulli(0.8)).collect();
            let mut packed = SpikeWords::new(n_pre, batch);
            packed.fill_from_bools(&dense);
            let mask = mask_words(&active);

            let mut out_packed = vec![0.0f32; n_post * batch];
            matvec_spikes_packed(&w, false, &packed, n_post, batch, &mask, &mut out_packed);
            let mut out_dense = vec![0.0f32; n_post * batch];
            matvec_spikes_batch(
                &w, false, &dense, n_pre, n_post, batch, &active, &mut out_dense,
            );
            assert_eq!(out_packed, out_dense, "batch {batch}");

            // shared-weight (fixed mode) variant
            let wshared = &w[..n_pre * n_post];
            let mut out_p = vec![0.0f32; n_post * batch];
            matvec_spikes_packed(wshared, true, &packed, n_post, batch, &mask, &mut out_p);
            let mut out_d = vec![0.0f32; n_post * batch];
            matvec_spikes_batch(
                wshared, true, &dense, n_pre, n_post, batch, &active, &mut out_d,
            );
            assert_eq!(out_p, out_d, "shared batch {batch}");
        }
    }

    #[test]
    fn f16_network_tracks_f32_network() {
        let cfg = SnnConfig::tiny();
        let mut rng = Pcg64::new(8, 0);
        let mut flat = vec![0.0f32; cfg.n_rule_params()];
        rng.fill_normal_f32(&mut flat, 0.2);
        let rule = NetworkRule::from_flat(&cfg, &flat);

        let mut a = SnnNetwork::<f32>::new(cfg.clone(), Mode::Plastic(rule.clone().into()));
        let mut b = SnnNetwork::<F16>::new(cfg.clone(), Mode::Plastic(rule.into()));
        let mut input_rng = Pcg64::new(9, 0);
        let mut spike_agreement = 0usize;
        let mut total = 0usize;
        for _ in 0..60 {
            let spikes: Vec<bool> = (0..cfg.n_in).map(|_| input_rng.bernoulli(0.5)).collect();
            let oa: Vec<bool> = a.step_spikes(&spikes).to_vec();
            let ob: Vec<bool> = b.step_spikes(&spikes).to_vec();
            spike_agreement += oa.iter().zip(&ob).filter(|(x, y)| x == y).count();
            total += oa.len();
        }
        // FP16 quantization may flip borderline spikes, but behaviour
        // must stay closely aligned (paper argues FP16 suffices).
        let agreement = spike_agreement as f64 / total as f64;
        assert!(agreement > 0.9, "spike agreement only {agreement}");
    }

    #[test]
    fn batched_matches_sequential_singles() {
        // B sessions stepped through one batched plastic network must be
        // bit-identical to B independent single-session networks fed the
        // same per-session spike streams — the correctness contract the
        // batching server relies on.
        let cfg = SnnConfig::tiny();
        let batch = 4;
        let mut rng = Pcg64::new(21, 0);
        let mut flat = vec![0.0f32; cfg.n_rule_params()];
        rng.fill_normal_f32(&mut flat, 0.25);
        let rule = NetworkRule::from_flat(&cfg, &flat);

        let mut batched =
            SnnNetwork::<f32>::new_batched(cfg.clone(), Mode::Plastic(rule.clone().into()), batch);
        let mut singles: Vec<SnnNetwork<f32>> = (0..batch)
            .map(|_| SnnNetwork::new(cfg.clone(), Mode::Plastic(rule.clone().into())))
            .collect();

        let active = vec![true; batch];
        let mut input_rng = Pcg64::new(22, 0);
        for _ in 0..40 {
            // independent spike stream per session, [neuron][session]
            let mut inmat = vec![false; cfg.n_in * batch];
            for b in 0..batch {
                for j in 0..cfg.n_in {
                    inmat[j * batch + b] = input_rng.bernoulli(0.4 + 0.1 * b as f64);
                }
            }
            batched.step_spikes_masked(&inmat, &active);
            for (b, single) in singles.iter_mut().enumerate() {
                let spikes: Vec<bool> = (0..cfg.n_in).map(|j| inmat[j * batch + b]).collect();
                single.step_spikes(&spikes);
                for o in 0..cfg.n_out {
                    assert_eq!(
                        batched.output.spikes.get(o, b),
                        single.output.spikes.get(o, 0),
                        "output spike mismatch session {b} neuron {o}"
                    );
                }
            }
        }
        // weights bit-exact per session after 40 plastic steps
        for (b, single) in singles.iter().enumerate() {
            for s in 0..cfg.l1_synapses() {
                assert_eq!(batched.w1[s * batch + b], single.w1[s], "w1 s{b} syn{s}");
            }
            for s in 0..cfg.l2_synapses() {
                assert_eq!(batched.w2[s * batch + b], single.w2[s], "w2 s{b} syn{s}");
            }
            assert_eq!(
                batched.output_traces_f32_session(b),
                single.output_traces_f32()
            );
        }
    }

    #[test]
    fn masked_sessions_do_not_advance() {
        let cfg = SnnConfig::tiny();
        let batch = 3;
        let mut rng = Pcg64::new(23, 0);
        let mut flat = vec![0.0f32; cfg.n_rule_params()];
        rng.fill_normal_f32(&mut flat, 0.3);
        let rule = NetworkRule::from_flat(&cfg, &flat);
        let mut net =
            SnnNetwork::<f32>::new_batched(cfg.clone(), Mode::Plastic(rule.into()), batch);

        let mut inmat = vec![true; cfg.n_in * batch];
        // session 1 inactive: even with garbage input bits set, its state
        // must stay exactly zero
        for j in 0..cfg.n_in {
            inmat[j * batch + 1] = true;
        }
        let active = [true, false, true];
        for _ in 0..30 {
            net.step_spikes_masked(&inmat, &active);
        }
        for s in 0..cfg.l1_synapses() {
            assert_eq!(net.w1[s * batch + 1], 0.0, "masked session weight moved");
        }
        for o in 0..cfg.n_out {
            assert_eq!(net.trace_out.values[o * batch + 1], 0.0);
        }
        // active sessions did move
        assert!(net.w1.iter().any(|&w| w != 0.0));

        // per-session reset clears only that column
        net.reset_session(0);
        for s in 0..cfg.l1_synapses().min(64) {
            assert_eq!(net.w1[s * batch], 0.0);
        }
        assert!(
            (0..cfg.l1_synapses()).any(|s| net.w1[s * batch + 2] != 0.0),
            "session 2 must survive session 0's reset"
        );
    }

    #[test]
    fn batched_fixed_mode_shares_one_weight_copy() {
        let cfg = SnnConfig::tiny();
        let mut net = SnnNetwork::<f32>::new_batched(cfg.clone(), Mode::Fixed, 8);
        assert_eq!(net.w1.len(), cfg.l1_synapses(), "fixed weights must not replicate");
        let mut rng = Pcg64::new(24, 0);
        let mut flat = vec![0.0f32; cfg.n_weights()];
        rng.fill_normal_f32(&mut flat, 1.0);
        net.load_weights(&flat);
        let active = vec![true; 8];
        let inmat = vec![true; cfg.n_in * 8];
        net.step_spikes_masked(&inmat, &active);
        // identical inputs + shared weights → identical outputs per session
        for o in 0..cfg.n_out {
            let first = net.output.spikes.get(o, 0);
            for b in 1..8 {
                assert_eq!(net.output.spikes.get(o, b), first);
            }
        }
    }

    #[test]
    fn step_currents_matches_thresholded_step_spikes() {
        let cfg = SnnConfig::tiny();
        let mut rng = Pcg64::new(25, 0);
        let mut flat = vec![0.0f32; cfg.n_rule_params()];
        rng.fill_normal_f32(&mut flat, 0.3);
        let rule = NetworkRule::from_flat(&cfg, &flat);
        let mut a = SnnNetwork::<f32>::new(cfg.clone(), Mode::Plastic(rule.clone().into()));
        let mut b = SnnNetwork::<f32>::new(cfg.clone(), Mode::Plastic(rule.into()));
        for t in 0..20 {
            let currents: Vec<f32> = (0..cfg.n_in)
                .map(|j| ((j + t) % 4) as f32 * 0.3)
                .collect();
            let spikes: Vec<bool> = currents.iter().map(|&c| c > 0.5).collect();
            let oa: Vec<bool> = a.step_currents(&currents).to_vec();
            let ob: Vec<bool> = b.step_spikes(&spikes).to_vec();
            assert_eq!(oa, ob);
        }
        assert_eq!(a.w1, b.w1);
    }

    #[test]
    fn grow_batch_preserves_live_sessions() {
        let cfg = SnnConfig::tiny();
        let mut rng = Pcg64::new(26, 0);
        let mut flat = vec![0.0f32; cfg.n_rule_params()];
        rng.fill_normal_f32(&mut flat, 0.3);
        let rule = NetworkRule::from_flat(&cfg, &flat);

        let batch = 2;
        let mut net =
            SnnNetwork::<f32>::new_batched(cfg.clone(), Mode::Plastic(rule.clone().into()), batch);
        let mut single = SnnNetwork::<f32>::new(cfg.clone(), Mode::Plastic(rule.into()));
        let active = vec![true; batch];
        let mut input_rng = Pcg64::new(27, 0);
        for _ in 0..15 {
            let mut inmat = vec![false; cfg.n_in * batch];
            for (k, v) in inmat.iter_mut().enumerate() {
                *v = input_rng.bernoulli(if k % batch == 0 { 0.5 } else { 0.3 });
            }
            net.step_spikes_masked(&inmat, &active);
            let chunk: Vec<bool> = (0..cfg.n_in).map(|j| inmat[j * batch]).collect();
            single.step_spikes(&chunk);
        }

        // grow past a word boundary; session 0 must keep tracking `single`
        net.grow_batch(66);
        assert_eq!(net.batch, 66);
        for s in 0..cfg.l1_synapses() {
            assert_eq!(net.w1[s * 66], single.w1[s], "w1 lost in grow, syn {s}");
        }
        let mut active66 = vec![false; 66];
        active66[0] = true;
        let mut input_rng2 = Pcg64::new(28, 0);
        for _ in 0..10 {
            let mut inmat = vec![false; cfg.n_in * 66];
            let chunk: Vec<bool> = (0..cfg.n_in).map(|_| input_rng2.bernoulli(0.5)).collect();
            for j in 0..cfg.n_in {
                inmat[j * 66] = chunk[j];
            }
            net.step_spikes_masked(&inmat, &active66);
            single.step_spikes(&chunk);
        }
        for s in 0..cfg.l1_synapses() {
            assert_eq!(net.w1[s * 66], single.w1[s], "post-grow drift, syn {s}");
        }
        assert_eq!(net.output_traces_f32_session(0), single.output_traces_f32());
        // new sessions start silent
        assert!(net.output_traces_f32_session(65).iter().all(|&t| t == 0.0));
    }

    #[test]
    fn packed_path_matches_scalar_reference() {
        // Direct pin against the dense scalar oracle (the full property
        // sweep lives in tests/packed_equivalence.rs).
        let cfg = SnnConfig::tiny();
        let mut rng = Pcg64::new(29, 0);
        let mut flat = vec![0.0f32; cfg.n_rule_params()];
        rng.fill_normal_f32(&mut flat, 0.25);
        let rule = NetworkRule::from_flat(&cfg, &flat);
        let mut packed = SnnNetwork::<f32>::new(cfg.clone(), Mode::Plastic(rule.clone().into()));
        let mut oracle = ReferenceNetwork::<f32>::new(cfg.clone(), Mode::Plastic(rule.into()));
        let mut input_rng = Pcg64::new(30, 0);
        for _ in 0..50 {
            let spikes: Vec<bool> = (0..cfg.n_in).map(|_| input_rng.bernoulli(0.4)).collect();
            let op: Vec<bool> = packed.step_spikes(&spikes).to_vec();
            let or: Vec<bool> = oracle.step_spikes(&spikes).to_vec();
            assert_eq!(op, or);
        }
        assert_eq!(packed.w1, oracle.w1);
        assert_eq!(packed.w2, oracle.w2);
        assert_eq!(packed.trace_out.values, oracle.trace_out);
        assert_eq!(packed.hidden.v, oracle.v_hidden);
    }

    #[test]
    fn gated_network_matches_gated_dense_oracle() {
        // Event-driven plasticity (lazy input traces + presyn gate) must
        // be bit-exact against the identically gated dense oracle — the
        // ε-contract lives between gated and ungated runs, never between
        // implementations. (The full sweep is in tests/lazy_traces.rs.)
        let mut cfg = SnnConfig::tiny();
        cfg.plasticity.presyn_gate = true;
        let batch = 5;
        let mut rng = Pcg64::new(90, 0);
        let mut flat = vec![0.0f32; cfg.n_rule_params()];
        rng.fill_normal_f32(&mut flat, 0.3);
        let rule = NetworkRule::from_flat(&cfg, &flat);
        let mut packed =
            SnnNetwork::<f32>::new_batched(cfg.clone(), Mode::Plastic(rule.clone().into()), batch);
        assert!(packed.trace_in.is_lazy(), "gated network must use lazy input traces");
        let mut dense = crate::snn::reference::DenseBatchedNetwork::<f32>::new(
            cfg.clone(),
            Mode::Plastic(rule.into()),
            batch,
        );
        let mut input_rng = Pcg64::new(91, 0);
        for step in 0..60 {
            let active: Vec<bool> = (0..batch).map(|b| (step + b) % 3 != 0).collect();
            // half the input rows permanently silent → the gate engages
            let inmat: Vec<bool> = (0..cfg.n_in * batch)
                .map(|k| (k / batch) % 2 == 0 && input_rng.bernoulli(0.4))
                .collect();
            packed.step_spikes_masked(&inmat, &active);
            dense.step_spikes_masked(&inmat, &active);
            assert_eq!(
                packed.plasticity_rows_visited, dense.plasticity_rows_visited,
                "gate decisions diverged at step {step}"
            );
            assert!(
                packed.plasticity_rows_visited[0] < cfg.n_in,
                "gate never engaged on L1"
            );
        }
        assert_eq!(packed.w1, dense.w1);
        assert_eq!(packed.w2, dense.w2);
        assert_eq!(packed.trace_in.values, dense.trace_in);
        assert_eq!(packed.trace_out.values, dense.trace_out);
    }

    #[test]
    fn steady_state_step_is_allocation_free_observable() {
        // Proxy check: repeated stepping does not grow weight/trace
        // buffer lengths (the real counting-allocator assertion lives in
        // tests/alloc_free_serving.rs; here we pin the state sizes the
        // hot loop touches).
        let cfg = SnnConfig::tiny();
        let rule = NetworkRule::zeros(&cfg);
        let mut net = SnnNetwork::<f32>::new(cfg.clone(), Mode::Plastic(rule.into()));
        let spikes = vec![true; cfg.n_in];
        let w1_cap = net.w1.capacity();
        for _ in 0..100 {
            net.step_spikes(&spikes);
        }
        assert_eq!(net.w1.capacity(), w1_cap);
        assert_eq!(net.steps, 100);
    }
}
