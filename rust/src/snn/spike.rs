//! Bit-packed spike words — the binary-activation representation of the
//! serving hot path (DESIGN.md §Hot-Path).
//!
//! Spikes are binary, so a population's activity across the batched
//! session lanes packs into `u64` words: session `b` of neuron `n` is
//! bit `b % 64` of word `n·wpr + b/64` (`wpr` = words per row, the batch
//! dimension rounded up to the 64-lane word width). This buys the two
//! tricks the FireFly line exploits in hardware (arXiv 2301.01905,
//! 2309.16158):
//!
//! - **event-driven skip**: a whole 64-session word compares against
//!   zero in one instruction, and a `trailing_zeros` walk visits only
//!   the set bits — synaptic accumulation cost scales with the firing
//!   rate, not with `n_pre × n_post × batch`;
//! - **branch-free masking**: the active-session mask is a word too, so
//!   masked batched stepping is bitwise AND + lane selects instead of a
//!   data-dependent branch per `(neuron, session)`.
//!
//! Lanes at or beyond the logical batch are **always zero** — every
//! writer below maintains that invariant, so kernels may walk whole
//! words without range checks.

/// Session lanes per packed spike word.
pub const LANES: usize = 64;

/// Number of `u64` words needed to hold `batch` session lanes.
#[inline]
pub const fn words_for(batch: usize) -> usize {
    batch.div_ceil(LANES)
}

/// Iterator over the set-bit positions of one packed word, ascending —
/// the `trailing_zeros` lane walk shared by the event-driven kernels
/// (cost ∝ set bits, not lanes). The two hottest kernels
/// (`matvec_spikes_packed`, the partial-mask arm of
/// `apply_update_batch`) keep the walk hand-inlined; every other
/// consumer goes through this single copy of the idiom.
#[inline]
pub fn set_bits(mut word: u64) -> impl Iterator<Item = usize> {
    std::iter::from_fn(move || {
        if word == 0 {
            return None;
        }
        let bit = word.trailing_zeros() as usize;
        word &= word - 1;
        Some(bit)
    })
}

/// Pack a boolean active-session mask into words (`words.len()` must be
/// `words_for(active.len())`). Padding lanes are left zero.
pub fn pack_mask_into(active: &[bool], words: &mut [u64]) {
    assert_eq!(words.len(), words_for(active.len()), "mask word count mismatch");
    for (wi, word) in words.iter_mut().enumerate() {
        let lanes = (active.len() - wi * LANES).min(LANES);
        let mut bits = 0u64;
        for (l, &on) in active[wi * LANES..wi * LANES + lanes].iter().enumerate() {
            bits |= (on as u64) << l;
        }
        *word = bits;
    }
}

/// Allocating convenience wrapper around [`pack_mask_into`] (tests and
/// cold paths; the hot path keeps a scratch mask and packs in place).
pub fn mask_words(active: &[bool]) -> Vec<u64> {
    let mut words = vec![0u64; words_for(active.len())];
    pack_mask_into(active, &mut words);
    words
}

/// All-active mask over `batch` lanes (padding lanes zero).
pub fn full_mask(batch: usize) -> Vec<u64> {
    let mut words = vec![0u64; words_for(batch)];
    for (wi, w) in words.iter_mut().enumerate() {
        let lanes = (batch - wi * LANES).min(LANES);
        *w = if lanes == LANES { u64::MAX } else { (1u64 << lanes) - 1 };
    }
    words
}

/// Re-lay a `[element][session]` scalar buffer from `old_batch` lanes to
/// `new_batch` lanes, preserving existing sessions and filling the new
/// lanes with `fill`. Shared by the batched state carriers' `grow_batch`
/// (sessions must survive capacity growth — see
/// `SnnBackend::ensure_sessions`).
pub fn grow_lanes<T: Copy>(old: &[T], old_batch: usize, new_batch: usize, fill: T) -> Vec<T> {
    assert!(old_batch >= 1 && new_batch >= old_batch, "lanes can only grow");
    assert_eq!(old.len() % old_batch, 0, "buffer not a multiple of batch");
    let elems = old.len() / old_batch;
    let mut out = vec![fill; elems * new_batch];
    for e in 0..elems {
        out[e * new_batch..e * new_batch + old_batch]
            .copy_from_slice(&old[e * old_batch..(e + 1) * old_batch]);
    }
    out
}

/// Bit-packed binary spike matrix over `neurons × batch` session lanes.
///
/// Layout: `neurons` rows of `words_per_row` contiguous `u64` words;
/// session `b` of neuron `n` is bit `b % 64` of word
/// `n · words_per_row + b / 64`. Bits at lanes `>= batch` are always
/// zero (maintained by every mutator).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpikeWords {
    words: Vec<u64>,
    neurons: usize,
    batch: usize,
    words_per_row: usize,
}

impl SpikeWords {
    /// All-silent spike matrix for `neurons × batch`.
    pub fn new(neurons: usize, batch: usize) -> Self {
        assert!(batch >= 1, "batch must be >= 1");
        let words_per_row = words_for(batch);
        SpikeWords {
            words: vec![0; neurons * words_per_row],
            neurons,
            batch,
            words_per_row,
        }
    }

    /// Number of neurons (rows).
    #[inline]
    pub fn neurons(&self) -> usize {
        self.neurons
    }

    /// Number of session lanes carried per neuron.
    #[inline]
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Words per neuron row (`batch` rounded up to the 64-lane width).
    #[inline]
    pub fn words_per_row(&self) -> usize {
        self.words_per_row
    }

    /// One neuron's packed session lanes.
    #[inline]
    pub fn row(&self, neuron: usize) -> &[u64] {
        &self.words[neuron * self.words_per_row..(neuron + 1) * self.words_per_row]
    }

    /// Mutable access to one neuron's packed session lanes. Callers must
    /// keep lanes `>= batch` zero.
    #[inline]
    pub fn row_mut(&mut self, neuron: usize) -> &mut [u64] {
        &mut self.words[neuron * self.words_per_row..(neuron + 1) * self.words_per_row]
    }

    /// The whole packed word buffer (`neurons × words_per_row`,
    /// row-major) — the serialization view used by serving snapshots.
    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Overwrite the packed word buffer from a snapshot taken at the
    /// same `(neurons, batch)` geometry. The source must honour the
    /// padding invariant (lanes `>= batch` zero) — true of any buffer
    /// produced by [`SpikeWords::words`] at matching geometry.
    pub fn copy_words_from(&mut self, words: &[u64]) {
        assert_eq!(words.len(), self.words.len(), "spike word count mismatch");
        self.words.copy_from_slice(words);
    }

    /// Spike bit of (`neuron`, `session`).
    #[inline]
    pub fn get(&self, neuron: usize, session: usize) -> bool {
        assert!(neuron < self.neurons && session < self.batch, "spike index out of range");
        let w = neuron * self.words_per_row + session / LANES;
        (self.words[w] >> (session % LANES)) & 1 == 1
    }

    /// Set or clear the spike bit of (`neuron`, `session`).
    #[inline]
    pub fn set(&mut self, neuron: usize, session: usize, value: bool) {
        assert!(neuron < self.neurons && session < self.batch, "spike index out of range");
        let w = neuron * self.words_per_row + session / LANES;
        let bit = 1u64 << (session % LANES);
        if value {
            self.words[w] |= bit;
        } else {
            self.words[w] &= !bit;
        }
    }

    /// Clear every spike bit.
    pub fn clear(&mut self) {
        for w in self.words.iter_mut() {
            *w = 0;
        }
    }

    /// Clear one session's lane across all neurons, leaving other
    /// sessions untouched.
    pub fn clear_session(&mut self, session: usize) {
        assert!(session < self.batch, "session out of range");
        let w = session / LANES;
        let mask = !(1u64 << (session % LANES));
        for n in 0..self.neurons {
            self.words[n * self.words_per_row + w] &= mask;
        }
    }

    /// Repack from a dense `[neuron][session]` boolean matrix
    /// (`bools.len() == neurons × batch`).
    pub fn fill_from_bools(&mut self, bools: &[bool]) {
        assert_eq!(bools.len(), self.neurons * self.batch, "spike matrix size mismatch");
        let (wpr, batch) = (self.words_per_row, self.batch);
        for n in 0..self.neurons {
            let base = n * batch;
            for wi in 0..wpr {
                let lanes = (batch - wi * LANES).min(LANES);
                let mut bits = 0u64;
                for (l, &s) in bools[base + wi * LANES..base + wi * LANES + lanes]
                    .iter()
                    .enumerate()
                {
                    bits |= (s as u64) << l;
                }
                self.words[n * wpr + wi] = bits;
            }
        }
    }

    /// Unpack into a dense `[neuron][session]` boolean matrix
    /// (`out.len() == neurons × batch`).
    pub fn write_bools(&self, out: &mut [bool]) {
        assert_eq!(out.len(), self.neurons * self.batch, "spike matrix size mismatch");
        for n in 0..self.neurons {
            let row = self.row(n);
            for b in 0..self.batch {
                out[n * self.batch + b] = (row[b / LANES] >> (b % LANES)) & 1 == 1;
            }
        }
    }

    /// Total number of set spike bits (diagnostics).
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// True if any spike bit is set.
    pub fn any(&self) -> bool {
        self.words.iter().any(|&w| w != 0)
    }

    /// Grow the session dimension to `new_batch`, preserving every
    /// existing session's bits (lane positions are stable under growth)
    /// and leaving the new lanes silent.
    pub fn grow_batch(&mut self, new_batch: usize) {
        assert!(new_batch >= self.batch, "batch can only grow");
        if new_batch == self.batch {
            return;
        }
        let new_wpr = words_for(new_batch);
        let mut new_words = vec![0u64; self.neurons * new_wpr];
        for n in 0..self.neurons {
            let src = &self.words[n * self.words_per_row..(n + 1) * self.words_per_row];
            new_words[n * new_wpr..n * new_wpr + self.words_per_row].copy_from_slice(src);
        }
        self.words = new_words;
        self.batch = new_batch;
        self.words_per_row = new_wpr;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_round_trip_across_word_boundary() {
        let mut s = SpikeWords::new(3, 70); // 2 words per row
        assert_eq!(s.words_per_row(), 2);
        s.set(1, 0, true);
        s.set(1, 63, true);
        s.set(1, 64, true);
        s.set(2, 69, true);
        assert!(s.get(1, 0) && s.get(1, 63) && s.get(1, 64) && s.get(2, 69));
        assert!(!s.get(0, 0) && !s.get(1, 1) && !s.get(2, 68));
        assert_eq!(s.count_ones(), 4);
        s.set(1, 63, false);
        assert!(!s.get(1, 63));
        assert_eq!(s.count_ones(), 3);
    }

    #[test]
    fn bools_round_trip() {
        let (n, b) = (5, 67);
        let mut dense = vec![false; n * b];
        let mut x = 0x1234_5678u64;
        for v in dense.iter_mut() {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
            *v = x >> 60 > 7;
        }
        let mut s = SpikeWords::new(n, b);
        s.fill_from_bools(&dense);
        let mut back = vec![false; n * b];
        s.write_bools(&mut back);
        assert_eq!(dense, back);
        // padding lanes stay zero
        for row in 0..n {
            assert_eq!(s.row(row)[1] >> (b - LANES), 0, "padding lanes must be zero");
        }
    }

    #[test]
    fn set_bits_walks_ascending() {
        assert_eq!(set_bits(0).count(), 0);
        assert_eq!(set_bits(0b1011).collect::<Vec<_>>(), vec![0, 1, 3]);
        assert_eq!(set_bits(1u64 << 63).collect::<Vec<_>>(), vec![63]);
        assert_eq!(set_bits(u64::MAX).count(), 64);
        assert_eq!(set_bits(u64::MAX).last(), Some(63));
    }

    #[test]
    fn mask_packing_and_full_mask() {
        let active = [true, false, true, true];
        let m = mask_words(&active);
        assert_eq!(m, vec![0b1101]);
        assert_eq!(full_mask(64), vec![u64::MAX]);
        assert_eq!(full_mask(65), vec![u64::MAX, 1]);
        assert_eq!(words_for(1), 1);
        assert_eq!(words_for(64), 1);
        assert_eq!(words_for(65), 2);
    }

    #[test]
    fn clear_session_only_touches_one_lane() {
        let mut s = SpikeWords::new(2, 3);
        s.set(0, 0, true);
        s.set(0, 1, true);
        s.set(1, 1, true);
        s.clear_session(1);
        assert!(s.get(0, 0));
        assert!(!s.get(0, 1) && !s.get(1, 1));
    }

    #[test]
    fn grow_preserves_lane_positions() {
        let mut s = SpikeWords::new(2, 3);
        s.set(0, 2, true);
        s.set(1, 0, true);
        s.grow_batch(130);
        assert_eq!(s.batch(), 130);
        assert_eq!(s.words_per_row(), 3);
        assert!(s.get(0, 2) && s.get(1, 0));
        assert_eq!(s.count_ones(), 2);
    }

    #[test]
    fn grow_lanes_preserves_sessions() {
        let old = vec![1.0f32, 2.0, 3.0, 4.0]; // 2 elements × 2 lanes
        let new = grow_lanes(&old, 2, 5, 0.0f32);
        assert_eq!(new, vec![1.0, 2.0, 0.0, 0.0, 0.0, 3.0, 4.0, 0.0, 0.0, 0.0]);
    }
}
