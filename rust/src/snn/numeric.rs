//! Numeric abstraction over the two arithmetic domains the system runs
//! in: host `f32` (ES rollouts, XLA artifact) and bit-accurate IEEE
//! binary16 [`F16`] (the FPGA datapath, §III-A of the paper).
//!
//! Every operation on [`Scalar`] rounds like a native ALU of that width:
//! for `F16` each op converts to f32, computes, and rounds back with RNE —
//! exactly one rounding per operation, matching a hardware FP16 FPU.

use crate::util::fp16::F16;

/// A scalar the SNN core can compute in.
pub trait Scalar: Copy + Clone + PartialOrd + std::fmt::Debug + Send + Sync + 'static {
    /// Additive identity.
    const ZERO: Self;
    /// Multiplicative identity.
    const ONE: Self;

    /// Quantize from host f32 (one rounding for `F16`).
    fn from_f32(x: f32) -> Self;
    /// Widen back to host f32 (exact for both domains).
    fn to_f32(self) -> f32;

    /// Addition with the domain's rounding.
    fn add(self, rhs: Self) -> Self;
    /// Subtraction with the domain's rounding.
    fn sub(self, rhs: Self) -> Self;
    /// Multiplication with the domain's rounding.
    fn mul(self, rhs: Self) -> Self;

    /// `self * a + b` with the rounding profile of the target hardware:
    /// f32 uses the host FMA; F16 models a DSP multiply-accumulate with a
    /// wide internal accumulator (single terminal rounding).
    fn mul_add(self, a: Self, b: Self) -> Self;

    /// Halve (the τ_m = 2 LIF leak is implemented in hardware as a
    /// shift/exponent decrement, never a multiplier — §III-B).
    fn half(self) -> Self;

    /// Saturating add used for weight accumulation (hardware saturates
    /// rather than overflowing to ±inf).
    fn saturating_add(self, rhs: Self) -> Self;

    /// Clamp into `[lo, hi]` (the weight-clip backstop).
    fn clamp(self, lo: Self, hi: Self) -> Self;

    /// False for NaN/±inf (stability diagnostics).
    fn is_finite(self) -> bool;
}

impl Scalar for f32 {
    const ZERO: f32 = 0.0;
    const ONE: f32 = 1.0;

    #[inline]
    fn from_f32(x: f32) -> f32 {
        x
    }
    #[inline]
    fn to_f32(self) -> f32 {
        self
    }
    #[inline]
    fn add(self, rhs: f32) -> f32 {
        self + rhs
    }
    #[inline]
    fn sub(self, rhs: f32) -> f32 {
        self - rhs
    }
    #[inline]
    fn mul(self, rhs: f32) -> f32 {
        self * rhs
    }
    #[inline]
    fn mul_add(self, a: f32, b: f32) -> f32 {
        f32::mul_add(self, a, b)
    }
    #[inline]
    fn half(self) -> f32 {
        self * 0.5
    }
    #[inline]
    fn saturating_add(self, rhs: f32) -> f32 {
        let s = self + rhs;
        s.clamp(f32::MIN, f32::MAX)
    }
    #[inline]
    fn clamp(self, lo: f32, hi: f32) -> f32 {
        f32::clamp(self, lo, hi)
    }
    #[inline]
    fn is_finite(self) -> bool {
        f32::is_finite(self)
    }
}

impl Scalar for F16 {
    const ZERO: F16 = F16(0x0000);
    const ONE: F16 = F16(0x3C00);

    #[inline]
    fn from_f32(x: f32) -> F16 {
        F16::from_f32(x)
    }
    #[inline]
    fn to_f32(self) -> f32 {
        F16::to_f32(self)
    }
    #[inline]
    fn add(self, rhs: F16) -> F16 {
        self + rhs
    }
    #[inline]
    fn sub(self, rhs: F16) -> F16 {
        self - rhs
    }
    #[inline]
    fn mul(self, rhs: F16) -> F16 {
        self * rhs
    }
    #[inline]
    fn mul_add(self, a: F16, b: F16) -> F16 {
        F16::mul_add(self, a, b)
    }
    #[inline]
    fn half(self) -> F16 {
        // Exponent decrement: exact for normals; for subnormals shift the
        // significand (exact halving in binary16 too, except sub-LSB which
        // rounds — matching a barrel-shift hardware leak unit with RNE).
        F16::from_f32(self.to_f32() * 0.5)
    }
    #[inline]
    fn saturating_add(self, rhs: F16) -> F16 {
        F16::from_f32_saturating(self.to_f32() + rhs.to_f32())
    }
    #[inline]
    fn clamp(self, lo: F16, hi: F16) -> F16 {
        self.max(lo).min(hi)
    }
    #[inline]
    fn is_finite(self) -> bool {
        F16::is_finite(self)
    }
}

/// Quantize an f32 slice into the scalar domain.
pub fn quantize_slice<S: Scalar>(xs: &[f32]) -> Vec<S> {
    xs.iter().map(|&x| S::from_f32(x)).collect()
}

/// Dequantize back to f32 (for metrics / comparison).
pub fn dequantize_slice<S: Scalar>(xs: &[S]) -> Vec<f32> {
    xs.iter().map(|x| x.to_f32()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_identity() {
        assert_eq!(f32::from_f32(1.25), 1.25);
        assert_eq!(1.5f32.half(), 0.75);
        assert_eq!(2.0f32.mul_add(3.0, 1.0), 7.0);
    }

    #[test]
    fn f16_rounds_per_op() {
        // 1 + 2^-11 rounds to 1 in f16, so adding it twice stays at 1 —
        // while f32 would accumulate. This is the per-op rounding the
        // hardware exhibits.
        let one = F16::ONE;
        let tiny = F16::from_f32(2f32.powi(-11));
        assert!(tiny.to_f32() > 0.0); // representable as subnormal-ish value itself
        let r = one.add(tiny).add(tiny);
        assert_eq!(r.to_f32(), 1.0);
    }

    #[test]
    fn half_is_exact_for_normals() {
        for x in [1.0f32, 3.0, 0.125, -7.5, 1000.0] {
            let h = F16::from_f32(x).half();
            assert_eq!(h.to_f32(), x / 2.0);
        }
    }

    #[test]
    fn saturating_add_clamps() {
        let max = F16::from_f32(65504.0);
        let r = max.saturating_add(max);
        assert_eq!(r.to_f32(), 65504.0);
        let r = (F16::from_f32(-65504.0)).saturating_add(F16::from_f32(-65504.0));
        assert_eq!(r.to_f32(), -65504.0);
    }

    #[test]
    fn quantize_round_trip() {
        let xs = vec![0.1f32, -2.5, 100.0];
        let q: Vec<F16> = quantize_slice(&xs);
        let back = dequantize_slice(&q);
        for (a, b) in xs.iter().zip(back.iter()) {
            assert!((a - b).abs() / a.abs().max(1.0) < 1e-3);
        }
    }
}
