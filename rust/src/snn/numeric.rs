//! Numeric abstraction over the three arithmetic domains the system runs
//! in: host `f32` (ES rollouts, XLA artifact), bit-accurate IEEE binary16
//! [`F16`] (the FPGA datapath, §III-A of the paper), and integer Q5.10
//! fixed-point [`Qfx`] (the hardware-parity DSP datapath,
//! [`crate::util::fixed`]).
//!
//! Every operation on [`Scalar`] rounds like a native ALU of that width:
//! for `F16` each op converts to f32, computes, and rounds back with RNE —
//! exactly one rounding per operation, matching a hardware FP16 FPU. For
//! `Qfx` each op is exact double-width integer arithmetic with a single
//! RNE requantization (multiplies) and saturation (adds) — a DSP slice.
//!
//! ## The non-finite contract (identical in every domain)
//!
//! [`Scalar::saturating_add`] guards weight accumulation, so its edge
//! behaviour is part of the cross-domain contract:
//!
//! - an overflowing or infinite sum **saturates** to the domain's largest
//!   finite magnitude (±[`f32::MAX`], ±65504 for `F16`,
//!   [`Qfx::MAX`]/[`Qfx::MIN`]);
//! - a NaN sum (NaN operand, or ∞ − ∞) collapses to **`ZERO`** — the one
//!   value every domain represents that keeps the weight finite and the
//!   poisoned update inert. `Qfx` satisfies this by construction: NaN
//!   cannot enter the domain ([`Qfx::from_f32`] quantizes NaN to zero),
//!   so its adder never sees one.
//!
//! The f32 impl originally propagated NaN here (`clamp` on NaN returns
//! NaN) while F16 returned its NaN encoding — the domains disagreed and
//! neither kept weights finite; the contract above is pinned by the
//! `saturating_add_*` tests below.

use crate::util::fixed::Qfx;
use crate::util::fp16::{F16, F16_MAX};

/// A scalar the SNN core can compute in.
pub trait Scalar: Copy + Clone + PartialOrd + std::fmt::Debug + Send + Sync + 'static {
    /// Additive identity.
    const ZERO: Self;
    /// Multiplicative identity.
    const ONE: Self;

    /// Quantize from host f32 (one rounding for `F16`).
    fn from_f32(x: f32) -> Self;
    /// Widen back to host f32 (exact for both domains).
    fn to_f32(self) -> f32;

    /// Addition with the domain's rounding.
    fn add(self, rhs: Self) -> Self;
    /// Subtraction with the domain's rounding.
    fn sub(self, rhs: Self) -> Self;
    /// Multiplication with the domain's rounding.
    fn mul(self, rhs: Self) -> Self;

    /// `self * a + b` with the rounding profile of the target hardware:
    /// f32 uses the host FMA; F16 models a DSP multiply-accumulate with a
    /// wide internal accumulator (single terminal rounding).
    fn mul_add(self, a: Self, b: Self) -> Self;

    /// Halve (the τ_m = 2 LIF leak is implemented in hardware as a
    /// shift/exponent decrement, never a multiplier — §III-B).
    fn half(self) -> Self;

    /// Saturating add used for weight accumulation (hardware saturates
    /// rather than overflowing to ±inf).
    ///
    /// Edge contract, identical across domains (see the module docs):
    /// an overflowing/infinite sum saturates to the largest finite
    /// magnitude; a NaN sum collapses to `ZERO`.
    fn saturating_add(self, rhs: Self) -> Self;

    /// Clamp into `[lo, hi]` (the weight-clip backstop).
    fn clamp(self, lo: Self, hi: Self) -> Self;

    /// False for NaN/±inf (stability diagnostics).
    fn is_finite(self) -> bool;

    /// The raw storage bits, zero-extended to `u32` — the canonical
    /// fingerprint for bit-exactness conformance (f32: the IEEE bit
    /// pattern; F16: the `u16` pattern; Qfx: the two's-complement
    /// payload reinterpreted as `u16`).
    fn bit_pattern(self) -> u32;

    /// Exact inverse of [`Scalar::bit_pattern`]: reconstruct the scalar
    /// from its zero-extended storage bits. Round-trips every value of
    /// the domain bit-for-bit (serving-snapshot durability relies on
    /// this); bits outside the domain's storage width are ignored, the
    /// way narrowing stores behave in hardware.
    fn from_bit_pattern(bits: u32) -> Self;

    /// Wire tag identifying this scalar domain in serialized state
    /// (serving snapshots refuse to restore across domains): `0x0F32`
    /// for f32, `0x0F16` for F16, `0x05A0` for Q5.10 Qfx.
    const PREC_TAG: u16;

    /// Quantize a **positive gate threshold** (the plasticity ε of
    /// `PlasticityConfig::trace_eps`), rounding *up* to the domain's next
    /// representable value instead of to-nearest.
    ///
    /// Rationale: the ε-gate skips a synapse row only when every active
    /// presynaptic trace is *below* ε. RNE quantization of a sub-quantum
    /// threshold would round it to zero, and `trace < 0` never holds — the
    /// gate would silently disengage in coarse domains (Qfx's quantum is
    /// 2⁻¹⁰; the FP16-aware default ε = 2⁻²⁴ is far below it) while the
    /// lazy-trace hot-mask prefilter, which tests the f32 ε, kept
    /// skipping — the two gate tiers would disagree. Ceiling quantization
    /// floors ε at the smallest positive representable value, so "below
    /// ε" degrades to exactly "no representable drive at this domain's
    /// granularity": in Qfx a skipped row is one whose traces are all
    /// *exactly* zero — precisely the rows the hot-mask prefilter skips,
    /// and lossless for γ = δ = 0 rules. For thresholds the domain
    /// represents exactly (ε = 2⁻²⁴ in f32 and F16) this is the identity,
    /// so the FP16 ε-tolerance contract of `PlasticityConfig` is
    /// unchanged.
    fn quantize_threshold(x: f32) -> Self;
}

impl Scalar for f32 {
    const ZERO: f32 = 0.0;
    const ONE: f32 = 1.0;

    #[inline]
    fn from_f32(x: f32) -> f32 {
        x
    }
    #[inline]
    fn to_f32(self) -> f32 {
        self
    }
    #[inline]
    fn add(self, rhs: f32) -> f32 {
        self + rhs
    }
    #[inline]
    fn sub(self, rhs: f32) -> f32 {
        self - rhs
    }
    #[inline]
    fn mul(self, rhs: f32) -> f32 {
        self * rhs
    }
    #[inline]
    fn mul_add(self, a: f32, b: f32) -> f32 {
        f32::mul_add(self, a, b)
    }
    #[inline]
    fn half(self) -> f32 {
        self * 0.5
    }
    #[inline]
    fn saturating_add(self, rhs: f32) -> f32 {
        let s = self + rhs;
        if s.is_nan() {
            // NaN sum → ZERO (cross-domain contract; `clamp` would
            // propagate the NaN).
            return 0.0;
        }
        s.clamp(f32::MIN, f32::MAX)
    }
    #[inline]
    fn clamp(self, lo: f32, hi: f32) -> f32 {
        f32::clamp(self, lo, hi)
    }
    #[inline]
    fn is_finite(self) -> bool {
        f32::is_finite(self)
    }
    #[inline]
    fn bit_pattern(self) -> u32 {
        self.to_bits()
    }
    #[inline]
    fn from_bit_pattern(bits: u32) -> f32 {
        f32::from_bits(bits)
    }
    const PREC_TAG: u16 = 0x0F32;
    #[inline]
    fn quantize_threshold(x: f32) -> f32 {
        x
    }
}

impl Scalar for F16 {
    const ZERO: F16 = F16(0x0000);
    const ONE: F16 = F16(0x3C00);

    #[inline]
    fn from_f32(x: f32) -> F16 {
        F16::from_f32(x)
    }
    #[inline]
    fn to_f32(self) -> f32 {
        F16::to_f32(self)
    }
    #[inline]
    fn add(self, rhs: F16) -> F16 {
        self + rhs
    }
    #[inline]
    fn sub(self, rhs: F16) -> F16 {
        self - rhs
    }
    #[inline]
    fn mul(self, rhs: F16) -> F16 {
        self * rhs
    }
    #[inline]
    fn mul_add(self, a: F16, b: F16) -> F16 {
        F16::mul_add(self, a, b)
    }
    #[inline]
    fn half(self) -> F16 {
        // Exponent decrement: exact for normals; for subnormals shift the
        // significand (exact halving in binary16 too, except sub-LSB which
        // rounds — matching a barrel-shift hardware leak unit with RNE).
        F16::from_f32(self.to_f32() * 0.5)
    }
    #[inline]
    fn saturating_add(self, rhs: F16) -> F16 {
        let s = self.to_f32() + rhs.to_f32();
        if s.is_nan() {
            // NaN sum → ZERO (cross-domain contract; `from_f32_saturating`
            // would return the NaN encoding).
            return <F16 as Scalar>::ZERO;
        }
        F16::from_f32_saturating(s)
    }
    #[inline]
    fn clamp(self, lo: F16, hi: F16) -> F16 {
        self.max(lo).min(hi)
    }
    #[inline]
    fn is_finite(self) -> bool {
        F16::is_finite(self)
    }
    #[inline]
    fn bit_pattern(self) -> u32 {
        self.0 as u32
    }
    #[inline]
    fn from_bit_pattern(bits: u32) -> F16 {
        F16(bits as u16)
    }
    const PREC_TAG: u16 = 0x0F16;
    #[inline]
    fn quantize_threshold(x: f32) -> F16 {
        // Ceiling quantization for positive thresholds: if RNE rounded
        // below x, bump one ulp (stopping at the largest finite value).
        let q = F16::from_f32_saturating(x);
        if x > 0.0 && q.to_f32() < x && q.0 < F16_MAX.0 {
            F16(q.0 + 1)
        } else {
            q
        }
    }
}

impl Scalar for Qfx {
    const ZERO: Qfx = Qfx::ZERO;
    const ONE: Qfx = Qfx::ONE;

    #[inline]
    fn from_f32(x: f32) -> Qfx {
        Qfx::from_f32(x)
    }
    #[inline]
    fn to_f32(self) -> f32 {
        Qfx::to_f32(self)
    }
    #[inline]
    fn add(self, rhs: Qfx) -> Qfx {
        // The DSP adder always saturates — there is no wrapping variant
        // in the datapath, so plain add and saturating_add coincide.
        self.sat_add(rhs)
    }
    #[inline]
    fn sub(self, rhs: Qfx) -> Qfx {
        self.sat_sub(rhs)
    }
    #[inline]
    fn mul(self, rhs: Qfx) -> Qfx {
        self.sat_mul(rhs)
    }
    #[inline]
    fn mul_add(self, a: Qfx, b: Qfx) -> Qfx {
        Qfx::mul_add(self, a, b)
    }
    #[inline]
    fn half(self) -> Qfx {
        // The hardware leak unit is an arithmetic shift with RNE on the
        // dropped bit — identical to multiplying by the exact 0.5.
        self.sat_mul(Qfx::HALF)
    }
    #[inline]
    fn saturating_add(self, rhs: Qfx) -> Qfx {
        self.sat_add(rhs)
    }
    #[inline]
    fn clamp(self, lo: Qfx, hi: Qfx) -> Qfx {
        Qfx(self.0.clamp(lo.0, hi.0))
    }
    #[inline]
    fn is_finite(self) -> bool {
        true
    }
    #[inline]
    fn bit_pattern(self) -> u32 {
        (self.0 as u16) as u32
    }
    #[inline]
    fn from_bit_pattern(bits: u32) -> Qfx {
        Qfx((bits as u16) as i16)
    }
    const PREC_TAG: u16 = 0x05A0;
    #[inline]
    fn quantize_threshold(x: f32) -> Qfx {
        if x.is_nan() {
            return Qfx::ZERO;
        }
        // Ceiling onto the Q5.10 grid: a sub-quantum positive ε floors
        // at one quantum, so the gate never silently disengages.
        let scaled = ((x as f64) * Qfx::SCALE as f64).ceil();
        if scaled >= i16::MAX as f64 {
            return Qfx::MAX;
        }
        if scaled <= i16::MIN as f64 {
            return Qfx::MIN;
        }
        Qfx(scaled as i16)
    }
}

/// Quantize an f32 slice into the scalar domain.
pub fn quantize_slice<S: Scalar>(xs: &[f32]) -> Vec<S> {
    xs.iter().map(|&x| S::from_f32(x)).collect()
}

/// Dequantize back to f32 (for metrics / comparison).
pub fn dequantize_slice<S: Scalar>(xs: &[S]) -> Vec<f32> {
    xs.iter().map(|x| x.to_f32()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_identity() {
        assert_eq!(f32::from_f32(1.25), 1.25);
        assert_eq!(1.5f32.half(), 0.75);
        assert_eq!(2.0f32.mul_add(3.0, 1.0), 7.0);
    }

    #[test]
    fn f16_rounds_per_op() {
        // 1 + 2^-11 rounds to 1 in f16, so adding it twice stays at 1 —
        // while f32 would accumulate. This is the per-op rounding the
        // hardware exhibits.
        let one = F16::ONE;
        let tiny = F16::from_f32(2f32.powi(-11));
        assert!(tiny.to_f32() > 0.0); // representable as subnormal-ish value itself
        let r = one.add(tiny).add(tiny);
        assert_eq!(r.to_f32(), 1.0);
    }

    #[test]
    fn half_is_exact_for_normals() {
        for x in [1.0f32, 3.0, 0.125, -7.5, 1000.0] {
            let h = F16::from_f32(x).half();
            assert_eq!(h.to_f32(), x / 2.0);
        }
    }

    #[test]
    fn saturating_add_clamps() {
        let max = F16::from_f32(65504.0);
        let r = max.saturating_add(max);
        assert_eq!(r.to_f32(), 65504.0);
        let r = (F16::from_f32(-65504.0)).saturating_add(F16::from_f32(-65504.0));
        assert_eq!(r.to_f32(), -65504.0);
    }

    #[test]
    fn quantize_round_trip() {
        let xs = vec![0.1f32, -2.5, 100.0];
        let q: Vec<F16> = quantize_slice(&xs);
        let back = dequantize_slice(&q);
        for (a, b) in xs.iter().zip(back.iter()) {
            assert!((a - b).abs() / a.abs().max(1.0) < 1e-3);
        }
    }

    #[test]
    fn saturating_add_nan_sum_collapses_to_zero() {
        // Regression (cross-domain contract): the f32 impl used to
        // propagate NaN (`clamp` on NaN returns NaN) and the F16 impl
        // returned its NaN encoding — both must yield ZERO.
        assert_eq!(Scalar::saturating_add(f32::NAN, 1.0f32).to_bits(), 0.0f32.to_bits());
        assert_eq!(Scalar::saturating_add(1.0f32, f32::NAN).to_bits(), 0.0f32.to_bits());
        assert_eq!(
            Scalar::saturating_add(f32::INFINITY, f32::NEG_INFINITY).to_bits(),
            0.0f32.to_bits()
        );
        let f16_nan = F16::from_f32(f32::NAN);
        assert_eq!(Scalar::saturating_add(f16_nan, <F16 as Scalar>::ONE).to_bits(), 0x0000);
        assert_eq!(Scalar::saturating_add(<F16 as Scalar>::ONE, f16_nan).to_bits(), 0x0000);
        let f16_inf = F16::from_f32(f32::INFINITY);
        assert_eq!(Scalar::saturating_add(f16_inf, -f16_inf).to_bits(), 0x0000);
        // Qfx holds the contract by construction: NaN never enters the
        // domain, so the adder cannot see one.
        assert_eq!(Qfx::from_f32(f32::NAN), Qfx::ZERO);
    }

    #[test]
    fn saturating_add_infinite_sum_saturates() {
        assert_eq!(Scalar::saturating_add(f32::INFINITY, 1.0f32), f32::MAX);
        assert_eq!(Scalar::saturating_add(f32::NEG_INFINITY, -1.0f32), f32::MIN);
        let f16_inf = F16::from_f32(f32::INFINITY);
        assert_eq!(Scalar::saturating_add(f16_inf, <F16 as Scalar>::ONE).to_bits(), F16_MAX.0);
        assert_eq!(
            Scalar::saturating_add(-f16_inf, <F16 as Scalar>::ONE).to_bits(),
            (-F16_MAX).to_bits()
        );
        assert_eq!(Scalar::saturating_add(Qfx::MAX, Qfx::ONE), Qfx::MAX);
        assert_eq!(Scalar::saturating_add(Qfx::MIN, -Qfx::ONE), Qfx::MIN);
    }

    #[test]
    fn saturating_add_cross_domain_property() {
        // For every domain and any inputs (including NaN/±inf injected at
        // quantization): the result of saturating_add is finite. This is
        // the whole point of the op — a weight can never leave the finite
        // range however poisoned the update is.
        fn probe<S: Scalar>(a: f32, b: f32, seed: u64) {
            let r = S::from_f32(a).saturating_add(S::from_f32(b));
            assert!(r.is_finite(), "saturating_add({a}, {b}) = {r:?} not finite (seed {seed:#x})");
        }
        crate::util::proptest::check(256, |g| {
            let pick = |g: &mut crate::util::proptest::Gen| match g.rng.below(5) {
                0 => f32::NAN,
                1 => f32::INFINITY,
                2 => f32::NEG_INFINITY,
                _ => g.edgy_f32(),
            };
            let a = pick(g);
            let b = pick(g);
            probe::<f32>(a, b, g.seed);
            probe::<F16>(a, b, g.seed);
            probe::<Qfx>(a, b, g.seed);
        });
    }

    #[test]
    fn qfx_scalar_matches_network_constants() {
        // The paper constants must be exactly representable so the Qfx
        // pipeline quantizes configs without drift.
        for exact in [0.5f32, 1.0, 2.0, 4.0, -4.0, 0.0] {
            assert_eq!(<Qfx as Scalar>::from_f32(exact).to_f32(), exact);
        }
        assert_eq!(<Qfx as Scalar>::ONE.half(), Qfx::HALF);
    }

    #[test]
    fn quantize_threshold_never_rounds_to_zero() {
        // The FP16-aware default ε = 2⁻²⁴ is sub-quantum in Qfx: ceiling
        // quantization floors it at one quantum instead of disengaging
        // the gate.
        let eps = 2f32.powi(-24);
        assert_eq!(<Qfx as Scalar>::quantize_threshold(eps), Qfx::EPSILON);
        // Exactly representable thresholds are unchanged in f32/F16
        // (2⁻²⁴ is the smallest F16 subnormal).
        assert_eq!(<f32 as Scalar>::quantize_threshold(eps), eps);
        assert_eq!(<F16 as Scalar>::quantize_threshold(eps).to_bits(), 0x0001);
        // A sub-subnormal threshold rounds *up* in F16 too.
        assert_eq!(<F16 as Scalar>::quantize_threshold(2f32.powi(-26)).to_bits(), 0x0001);
        // On-grid Qfx thresholds are identity.
        assert_eq!(<Qfx as Scalar>::quantize_threshold(0.25).to_f32(), 0.25);
        // Ceiling property: result is never below the requested threshold
        // unless saturated at the top of the domain.
        crate::util::proptest::check(128, |g| {
            let x = g.f32_range(1e-9, 8.0);
            let q = <Qfx as Scalar>::quantize_threshold(x);
            assert!(
                q.to_f32() >= x || q == Qfx::MAX,
                "threshold rounded down: {x} -> {q:?} (seed {:#x})",
                g.seed
            );
            assert!(q > Qfx::ZERO, "positive threshold collapsed to zero (seed {:#x})", g.seed);
        });
    }

    #[test]
    fn bit_pattern_is_storage_exact() {
        assert_eq!(1.0f32.bit_pattern(), 1.0f32.to_bits());
        assert_eq!(<F16 as Scalar>::ONE.bit_pattern(), 0x3C00);
        assert_eq!(Qfx::ONE.bit_pattern(), 1 << Qfx::FRAC);
        assert_eq!(Qfx(-1).bit_pattern(), 0xFFFF);
    }

    #[test]
    fn from_bit_pattern_round_trips_every_domain() {
        // The snapshot format stores every lane as its bit pattern;
        // restore must be the exact inverse — including non-canonical
        // encodings (negative zero, NaN payloads) that arithmetic could
        // have produced before the snapshot landed.
        for x in [0.0f32, -0.0, 1.5, -3.25, f32::MAX, f32::MIN_POSITIVE] {
            assert_eq!(f32::from_bit_pattern(x.bit_pattern()).to_bits(), x.to_bits());
        }
        // Exhaustive for the 16-bit domains: every u16 pattern survives.
        for bits in 0..=u16::MAX {
            let h = F16(bits);
            assert_eq!(F16::from_bit_pattern(h.bit_pattern()).0, bits);
            let q = Qfx(bits as i16);
            assert_eq!(Qfx::from_bit_pattern(q.bit_pattern()).0, bits as i16);
        }
        // High bits outside the storage width are ignored.
        assert_eq!(F16::from_bit_pattern(0xFFFF_3C00).0, 0x3C00);
        assert_eq!(Qfx::from_bit_pattern(0xABCD_0400).0, 0x0400);
    }
}
