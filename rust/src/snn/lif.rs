//! Leaky Integrate-and-Fire neuron dynamics (§II-A, §III-B).
//!
//! The paper's Neuron Dynamic Unit implements
//!
//! ```text
//! V(t) = V(t−1) + (1/τ_m)(I(t) − V(t−1)),   τ_m = 2
//! s(t) = 1 if V(t) > V_th else 0
//! ```
//!
//! With τ_m = 2 the update is `V ← V/2 + I/2` — a multiplier-free
//! shift-and-add, which is exactly how the Forward Engine realizes it
//! ("enables a multiplier-free implementation using only simple adders").
//! After a spike the membrane potential is reset by subtraction
//! (soft reset), preserving super-threshold drive.

use super::numeric::Scalar;

/// LIF population state: membrane potentials plus spike outputs.
///
/// Supports a structure-of-arrays **batch dimension** for multi-session
/// serving (see DESIGN.md §Batched-Serving): state is laid out
/// `[neuron][session]` so the per-neuron inner loop runs contiguously
/// over sessions. `batch == 1` (the [`LifLayer::new`] default) is
/// byte-identical to the historical single-session layout, so all
/// existing consumers (ES rollouts, the FPGA golden twin, MNIST) are
/// unaffected.
#[derive(Clone, Debug)]
pub struct LifLayer<S: Scalar> {
    /// Membrane potentials, `neurons × batch`, laid out `[neuron][session]`.
    pub v: Vec<S>,
    /// Spike outputs of the most recent step, same layout as `v`.
    pub spikes: Vec<bool>,
    /// Firing threshold shared by every neuron in the population.
    pub v_th: S,
    /// Soft reset: subtract V_th on spike (true, default) vs hard reset
    /// to zero (false). The FPGA design uses subtraction.
    pub soft_reset: bool,
    /// Number of independent sessions interleaved in `v`/`spikes`.
    pub batch: usize,
    /// Number of neurons in the population (`v.len() == neurons * batch`).
    pub neurons: usize,
}

impl<S: Scalar> LifLayer<S> {
    /// Single-session population of `n` neurons with threshold `v_th`.
    pub fn new(n: usize, v_th: f32) -> Self {
        Self::batched(n, 1, v_th)
    }

    /// Population of `n` neurons replicated across `batch` independent
    /// sessions (structure-of-arrays, `[neuron][session]`).
    pub fn batched(n: usize, batch: usize, v_th: f32) -> Self {
        assert!(batch >= 1, "batch must be >= 1");
        LifLayer {
            v: vec![S::ZERO; n * batch],
            spikes: vec![false; n * batch],
            v_th: S::from_f32(v_th),
            soft_reset: true,
            batch,
            neurons: n,
        }
    }

    /// Total state size (`neurons × batch`).
    pub fn len(&self) -> usize {
        self.v.len()
    }

    /// True when the population holds no neurons.
    pub fn is_empty(&self) -> bool {
        self.v.is_empty()
    }

    /// Zero every membrane potential and clear all spikes (all sessions).
    pub fn reset(&mut self) {
        for v in self.v.iter_mut() {
            *v = S::ZERO;
        }
        for s in self.spikes.iter_mut() {
            *s = false;
        }
    }

    /// Zero one session's column of membrane/spike state, leaving the
    /// other sessions untouched.
    pub fn reset_session(&mut self, session: usize) {
        assert!(session < self.batch, "session out of range");
        for i in 0..self.neurons {
            self.v[i * self.batch + session] = S::ZERO;
            self.spikes[i * self.batch + session] = false;
        }
    }

    /// Advance one timestep with input currents `i` (length must match).
    /// Returns the number of spikes emitted.
    pub fn step(&mut self, currents: &[S]) -> usize {
        assert_eq!(currents.len(), self.v.len(), "current/neuron mismatch");
        let mut fired = 0;
        for ((v, s), &i) in self.v.iter_mut().zip(self.spikes.iter_mut()).zip(currents) {
            // V ← V + (I − V)/2 computed as V/2 + I/2: two halvings and
            // one add, the exact dataflow of the multiplier-free unit.
            let nv = v.half().add(i.half());
            if nv > self.v_th {
                *s = true;
                fired += 1;
                *v = if self.soft_reset { nv.sub(self.v_th) } else { S::ZERO };
            } else {
                *s = false;
                *v = nv;
            }
        }
        fired
    }

    /// Batched step over the sessions selected by `active` (`active.len()
    /// == batch`). Inactive sessions' membrane and spike state are left
    /// exactly as they were — a session only advances when its client
    /// submitted an observation this tick. Per-session arithmetic and
    /// operation order are identical to [`LifLayer::step`], so a batched
    /// session is bit-equivalent to a single-session layer fed the same
    /// spike history. Returns the number of spikes emitted by active
    /// sessions.
    pub fn step_masked(&mut self, currents: &[S], active: &[bool]) -> usize {
        assert_eq!(currents.len(), self.v.len(), "current/neuron mismatch");
        assert_eq!(active.len(), self.batch, "mask/batch mismatch");
        let b = self.batch;
        let mut fired = 0;
        for i in 0..self.neurons {
            let row = i * b;
            for (k, &on) in active.iter().enumerate() {
                if !on {
                    continue;
                }
                let idx = row + k;
                let nv = self.v[idx].half().add(currents[idx].half());
                if nv > self.v_th {
                    self.spikes[idx] = true;
                    fired += 1;
                    self.v[idx] = if self.soft_reset {
                        nv.sub(self.v_th)
                    } else {
                        S::ZERO
                    };
                } else {
                    self.spikes[idx] = false;
                    self.v[idx] = nv;
                }
            }
        }
        fired
    }
}

/// Scalar single-neuron step (used by the FPGA simulator's Neuron Dynamic
/// Unit, which processes one neuron per PE per cycle).
#[inline]
pub fn lif_step_scalar<S: Scalar>(v: S, i: S, v_th: S, soft_reset: bool) -> (S, bool) {
    let nv = v.half().add(i.half());
    if nv > v_th {
        let reset = if soft_reset { nv.sub(v_th) } else { S::ZERO };
        (reset, true)
    } else {
        (nv, false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fp16::F16;

    #[test]
    fn integrates_toward_input() {
        let mut l = LifLayer::<f32>::new(1, 10.0); // high threshold: no spikes
        for _ in 0..64 {
            l.step(&[2.0]);
        }
        // Fixed point of V = V/2 + I/2 is I.
        assert!((l.v[0] - 2.0).abs() < 1e-4);
    }

    #[test]
    fn spikes_and_soft_resets() {
        let mut l = LifLayer::<f32>::new(1, 1.0);
        let mut spike_times = Vec::new();
        for t in 0..20 {
            let fired = l.step(&[4.0]);
            if fired > 0 {
                spike_times.push(t);
            }
        }
        assert!(!spike_times.is_empty());
        // With I=4: V converges to 4 > th, so after the first spike the
        // neuron fires regularly.
        assert!(spike_times.len() >= 10);
        // Soft reset keeps V positive after a spike with strong drive.
        assert!(l.v[0] > 0.0);
    }

    #[test]
    fn hard_reset_zeroes() {
        let mut l = LifLayer::<f32>::new(1, 1.0);
        l.soft_reset = false;
        // Drive hard for a few steps, check V==0 right after a spike step.
        let mut saw_spike = false;
        for _ in 0..10 {
            if l.step(&[10.0]) > 0 {
                assert_eq!(l.v[0], 0.0);
                saw_spike = true;
                break;
            }
        }
        assert!(saw_spike);
    }

    #[test]
    fn no_input_decays_to_zero() {
        let mut l = LifLayer::<f32>::new(1, 1.0);
        l.v[0] = 0.9;
        for _ in 0..40 {
            l.step(&[0.0]);
        }
        assert!(l.v[0].abs() < 1e-6);
    }

    #[test]
    fn f16_matches_f32_for_representable_values() {
        // Inputs chosen exactly representable in f16; the halving path is
        // exact, so both domains agree bit-for-bit here.
        let mut a = LifLayer::<f32>::new(1, 1.0);
        let mut b = LifLayer::<F16>::new(1, 1.0);
        for _ in 0..16 {
            a.step(&[0.5]);
            b.step(&[F16::from_f32(0.5)]);
            assert!((a.v[0] - b.v[0].to_f32()).abs() < 1e-3, "{} vs {}", a.v[0], b.v[0]);
            assert_eq!(a.spikes[0], b.spikes[0]);
        }
    }

    #[test]
    fn scalar_step_equals_layer_step() {
        let mut l = LifLayer::<f32>::new(3, 1.0);
        let mut v = [0.0f32; 3];
        let currents = [0.7f32, 1.3, 2.9];
        for _ in 0..10 {
            l.step(&currents);
            for k in 0..3 {
                let (nv, sp) = lif_step_scalar(v[k], currents[k], 1.0, true);
                v[k] = nv;
                assert_eq!(sp, l.spikes[k]);
                assert!((v[k] - l.v[k]).abs() < 1e-6);
            }
        }
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn wrong_current_len_panics() {
        let mut l = LifLayer::<f32>::new(2, 1.0);
        l.step(&[1.0]);
    }

    #[test]
    fn batched_sessions_match_independent_layers() {
        // Three sessions with different drive levels, stepped batched,
        // must match three independent single-session layers bit-for-bit.
        let n = 4;
        let batch = 3;
        let drives = [0.7f32, 1.6, 3.2];
        let mut batched = LifLayer::<f32>::batched(n, batch, 1.0);
        let mut singles: Vec<LifLayer<f32>> = (0..batch).map(|_| LifLayer::new(n, 1.0)).collect();
        let active = vec![true; batch];
        for _ in 0..25 {
            let mut currents = vec![0.0f32; n * batch];
            for i in 0..n {
                for b in 0..batch {
                    currents[i * batch + b] = drives[b] + i as f32 * 0.1;
                }
            }
            batched.step_masked(&currents, &active);
            for (b, single) in singles.iter_mut().enumerate() {
                let cur: Vec<f32> = (0..n).map(|i| currents[i * batch + b]).collect();
                single.step(&cur);
                for i in 0..n {
                    assert_eq!(batched.v[i * batch + b], single.v[i], "v mismatch s{b} n{i}");
                    assert_eq!(batched.spikes[i * batch + b], single.spikes[i]);
                }
            }
        }
    }

    #[test]
    fn masked_sessions_are_frozen() {
        let n = 2;
        let mut l = LifLayer::<f32>::batched(n, 2, 1.0);
        let currents = vec![4.0f32; n * 2];
        // advance only session 0; session 1 must stay at zero state
        l.step_masked(&currents, &[true, false]);
        l.step_masked(&currents, &[true, false]);
        for i in 0..n {
            assert!(l.v[i * 2] != 0.0 || l.spikes[i * 2]);
            assert_eq!(l.v[i * 2 + 1], 0.0);
            assert!(!l.spikes[i * 2 + 1]);
        }
        // reset_session clears only the requested column
        l.reset_session(0);
        for i in 0..n {
            assert_eq!(l.v[i * 2], 0.0);
            assert!(!l.spikes[i * 2]);
        }
    }
}
