//! Leaky Integrate-and-Fire neuron dynamics (§II-A, §III-B).
//!
//! The paper's Neuron Dynamic Unit implements
//!
//! ```text
//! V(t) = V(t−1) + (1/τ_m)(I(t) − V(t−1)),   τ_m = 2
//! s(t) = 1 if V(t) > V_th else 0
//! ```
//!
//! With τ_m = 2 the update is `V ← V/2 + I/2` — a multiplier-free
//! shift-and-add, which is exactly how the Forward Engine realizes it
//! ("enables a multiplier-free implementation using only simple adders").
//! After a spike the membrane potential is reset by subtraction
//! (soft reset), preserving super-threshold drive.

use super::numeric::Scalar;

/// LIF population state: membrane potentials plus spike outputs.
#[derive(Clone, Debug)]
pub struct LifLayer<S: Scalar> {
    pub v: Vec<S>,
    pub spikes: Vec<bool>,
    pub v_th: S,
    /// Soft reset: subtract V_th on spike (true, default) vs hard reset
    /// to zero (false). The FPGA design uses subtraction.
    pub soft_reset: bool,
}

impl<S: Scalar> LifLayer<S> {
    pub fn new(n: usize, v_th: f32) -> Self {
        LifLayer {
            v: vec![S::ZERO; n],
            spikes: vec![false; n],
            v_th: S::from_f32(v_th),
            soft_reset: true,
        }
    }

    pub fn len(&self) -> usize {
        self.v.len()
    }

    pub fn is_empty(&self) -> bool {
        self.v.is_empty()
    }

    pub fn reset(&mut self) {
        for v in self.v.iter_mut() {
            *v = S::ZERO;
        }
        for s in self.spikes.iter_mut() {
            *s = false;
        }
    }

    /// Advance one timestep with input currents `i` (length must match).
    /// Returns the number of spikes emitted.
    pub fn step(&mut self, currents: &[S]) -> usize {
        assert_eq!(currents.len(), self.v.len(), "current/neuron mismatch");
        let mut fired = 0;
        for ((v, s), &i) in self.v.iter_mut().zip(self.spikes.iter_mut()).zip(currents) {
            // V ← V + (I − V)/2 computed as V/2 + I/2: two halvings and
            // one add, the exact dataflow of the multiplier-free unit.
            let nv = v.half().add(i.half());
            if nv > self.v_th {
                *s = true;
                fired += 1;
                *v = if self.soft_reset { nv.sub(self.v_th) } else { S::ZERO };
            } else {
                *s = false;
                *v = nv;
            }
        }
        fired
    }
}

/// Scalar single-neuron step (used by the FPGA simulator's Neuron Dynamic
/// Unit, which processes one neuron per PE per cycle).
#[inline]
pub fn lif_step_scalar<S: Scalar>(v: S, i: S, v_th: S, soft_reset: bool) -> (S, bool) {
    let nv = v.half().add(i.half());
    if nv > v_th {
        let reset = if soft_reset { nv.sub(v_th) } else { S::ZERO };
        (reset, true)
    } else {
        (nv, false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fp16::F16;

    #[test]
    fn integrates_toward_input() {
        let mut l = LifLayer::<f32>::new(1, 10.0); // high threshold: no spikes
        for _ in 0..64 {
            l.step(&[2.0]);
        }
        // Fixed point of V = V/2 + I/2 is I.
        assert!((l.v[0] - 2.0).abs() < 1e-4);
    }

    #[test]
    fn spikes_and_soft_resets() {
        let mut l = LifLayer::<f32>::new(1, 1.0);
        let mut spike_times = Vec::new();
        for t in 0..20 {
            let fired = l.step(&[4.0]);
            if fired > 0 {
                spike_times.push(t);
            }
        }
        assert!(!spike_times.is_empty());
        // With I=4: V converges to 4 > th, so after the first spike the
        // neuron fires regularly.
        assert!(spike_times.len() >= 10);
        // Soft reset keeps V positive after a spike with strong drive.
        assert!(l.v[0] > 0.0);
    }

    #[test]
    fn hard_reset_zeroes() {
        let mut l = LifLayer::<f32>::new(1, 1.0);
        l.soft_reset = false;
        // Drive hard for a few steps, check V==0 right after a spike step.
        let mut saw_spike = false;
        for _ in 0..10 {
            if l.step(&[10.0]) > 0 {
                assert_eq!(l.v[0], 0.0);
                saw_spike = true;
                break;
            }
        }
        assert!(saw_spike);
    }

    #[test]
    fn no_input_decays_to_zero() {
        let mut l = LifLayer::<f32>::new(1, 1.0);
        l.v[0] = 0.9;
        for _ in 0..40 {
            l.step(&[0.0]);
        }
        assert!(l.v[0].abs() < 1e-6);
    }

    #[test]
    fn f16_matches_f32_for_representable_values() {
        // Inputs chosen exactly representable in f16; the halving path is
        // exact, so both domains agree bit-for-bit here.
        let mut a = LifLayer::<f32>::new(1, 1.0);
        let mut b = LifLayer::<F16>::new(1, 1.0);
        for _ in 0..16 {
            a.step(&[0.5]);
            b.step(&[F16::from_f32(0.5)]);
            assert!((a.v[0] - b.v[0].to_f32()).abs() < 1e-3, "{} vs {}", a.v[0], b.v[0]);
            assert_eq!(a.spikes[0], b.spikes[0]);
        }
    }

    #[test]
    fn scalar_step_equals_layer_step() {
        let mut l = LifLayer::<f32>::new(3, 1.0);
        let mut v = [0.0f32; 3];
        let currents = [0.7f32, 1.3, 2.9];
        for _ in 0..10 {
            l.step(&currents);
            for k in 0..3 {
                let (nv, sp) = lif_step_scalar(v[k], currents[k], 1.0, true);
                v[k] = nv;
                assert_eq!(sp, l.spikes[k]);
                assert!((v[k] - l.v[k]).abs() < 1e-6);
            }
        }
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn wrong_current_len_panics() {
        let mut l = LifLayer::<f32>::new(2, 1.0);
        l.step(&[1.0]);
    }
}
