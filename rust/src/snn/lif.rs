//! Leaky Integrate-and-Fire neuron dynamics (§II-A, §III-B).
//!
//! The paper's Neuron Dynamic Unit implements
//!
//! ```text
//! V(t) = V(t−1) + (1/τ_m)(I(t) − V(t−1)),   τ_m = 2
//! s(t) = 1 if V(t) > V_th else 0
//! ```
//!
//! With τ_m = 2 the update is `V ← V/2 + I/2` — a multiplier-free
//! shift-and-add, which is exactly how the Forward Engine realizes it
//! ("enables a multiplier-free implementation using only simple adders").
//! After a spike the membrane potential is reset by subtraction
//! (soft reset), preserving super-threshold drive.

use super::numeric::Scalar;
use super::spike::{grow_lanes, SpikeWords, LANES};
use super::trace::TraceVector;

/// LIF population state: membrane potentials plus bit-packed spike words.
///
/// Supports a structure-of-arrays **batch dimension** for multi-session
/// serving (see DESIGN.md §Batched-Serving): membranes are laid out
/// `[neuron][session]` so the per-neuron inner loop runs contiguously
/// over sessions, and the binary spike outputs are packed into `u64`
/// session words ([`SpikeWords`], DESIGN.md §Hot-Path) so downstream
/// synaptic accumulation can walk only the set bits. `batch == 1` (the
/// [`LifLayer::new`] default) keeps the historical single-session
/// membrane layout; spikes are read through [`SpikeWords::get`].
#[derive(Clone, Debug)]
pub struct LifLayer<S: Scalar> {
    /// Membrane potentials, `neurons × batch`, laid out `[neuron][session]`.
    pub v: Vec<S>,
    /// Bit-packed spike outputs of the most recent step.
    pub spikes: SpikeWords,
    /// Firing threshold shared by every neuron in the population.
    pub v_th: S,
    /// Soft reset: subtract V_th on spike (true, default) vs hard reset
    /// to zero (false). The FPGA design uses subtraction.
    pub soft_reset: bool,
    /// Number of independent sessions interleaved in `v`/`spikes`.
    pub batch: usize,
    /// Number of neurons in the population (`v.len() == neurons * batch`).
    pub neurons: usize,
}

impl<S: Scalar> LifLayer<S> {
    /// Single-session population of `n` neurons with threshold `v_th`.
    pub fn new(n: usize, v_th: f32) -> Self {
        Self::batched(n, 1, v_th)
    }

    /// Population of `n` neurons replicated across `batch` independent
    /// sessions (structure-of-arrays, `[neuron][session]`).
    pub fn batched(n: usize, batch: usize, v_th: f32) -> Self {
        assert!(batch >= 1, "batch must be >= 1");
        LifLayer {
            v: vec![S::ZERO; n * batch],
            spikes: SpikeWords::new(n, batch),
            v_th: S::from_f32(v_th),
            soft_reset: true,
            batch,
            neurons: n,
        }
    }

    /// Total state size (`neurons × batch`).
    pub fn len(&self) -> usize {
        self.v.len()
    }

    /// True when the population holds no neurons.
    pub fn is_empty(&self) -> bool {
        self.v.is_empty()
    }

    /// Zero every membrane potential and clear all spikes (all sessions).
    pub fn reset(&mut self) {
        for v in self.v.iter_mut() {
            *v = S::ZERO;
        }
        self.spikes.clear();
    }

    /// Zero one session's column of membrane/spike state, leaving the
    /// other sessions untouched.
    pub fn reset_session(&mut self, session: usize) {
        assert!(session < self.batch, "session out of range");
        for i in 0..self.neurons {
            self.v[i * self.batch + session] = S::ZERO;
        }
        self.spikes.clear_session(session);
    }

    /// Grow the session dimension to `new_batch`, preserving every
    /// existing session's membrane/spike state; new sessions start at
    /// rest.
    pub fn grow_batch(&mut self, new_batch: usize) {
        assert!(new_batch >= self.batch, "batch can only grow");
        if new_batch == self.batch {
            return;
        }
        self.v = grow_lanes(&self.v, self.batch, new_batch, S::ZERO);
        self.spikes.grow_batch(new_batch);
        self.batch = new_batch;
    }

    /// Advance one timestep with input currents `i` for **every** session
    /// (`currents.len() == neurons × batch`). Returns the number of
    /// spikes emitted.
    pub fn step(&mut self, currents: &[S]) -> usize {
        assert_eq!(currents.len(), self.v.len(), "current/neuron mismatch");
        let b = self.batch;
        let wpr = self.spikes.words_per_row();
        let mut fired = 0usize;
        for i in 0..self.neurons {
            for wi in 0..wpr {
                let lanes = (b - wi * LANES).min(LANES);
                let base = i * b + wi * LANES;
                let mut bits = 0u64;
                for l in 0..lanes {
                    let idx = base + l;
                    // Single-sourced datapath: V ← V/2 + I/2, compare,
                    // soft/hard reset — see `lif_step_scalar`.
                    let (nv, fire) =
                        lif_step_scalar(self.v[idx], currents[idx], self.v_th, self.soft_reset);
                    self.v[idx] = nv;
                    bits |= (fire as u64) << l;
                    fired += fire as usize;
                }
                self.spikes.row_mut(i)[wi] = bits;
            }
        }
        fired
    }

    /// Batched step over the sessions selected by the packed
    /// `active_words` mask (`active_words.len()` must equal
    /// `spikes.words_per_row()`; see [`crate::snn::spike::pack_mask_into`]).
    /// Inactive sessions' membrane and spike state are left exactly as
    /// they were — a session only advances when its client submitted an
    /// observation this tick. Per-session arithmetic and operation order
    /// are identical to [`LifLayer::step`], so a batched session is
    /// bit-equivalent to a single-session layer fed the same spike
    /// history. The lane loop is branch-free: inactive lanes compute and
    /// discard via select rather than branching. Returns the number of
    /// spikes emitted by active sessions.
    pub fn step_masked(&mut self, currents: &[S], active_words: &[u64]) -> usize {
        assert_eq!(currents.len(), self.v.len(), "current/neuron mismatch");
        assert_eq!(
            active_words.len(),
            self.spikes.words_per_row(),
            "mask/batch mismatch"
        );
        let b = self.batch;
        let mut fired = 0usize;
        for i in 0..self.neurons {
            for (wi, &aw) in active_words.iter().enumerate() {
                if aw == 0 {
                    continue; // whole word inactive: state frozen
                }
                let lanes = (b - wi * LANES).min(LANES);
                let base = i * b + wi * LANES;
                let mut bits = self.spikes.row(i)[wi] & !aw;
                for l in 0..lanes {
                    let on = (aw >> l) & 1 == 1;
                    let idx = base + l;
                    let old = self.v[idx];
                    let (stepped, fire) =
                        lif_step_scalar(old, currents[idx], self.v_th, self.soft_reset);
                    self.v[idx] = if on { stepped } else { old };
                    bits |= ((on && fire) as u64) << l;
                    fired += (on && fire) as usize;
                }
                self.spikes.row_mut(i)[wi] = bits;
            }
        }
        fired
    }

    /// Fused LIF step **plus** trace update over the masked sessions —
    /// one pass touches a neuron's membrane, spike word, and trace
    /// together instead of two separate sweeps (DESIGN.md §Hot-Path).
    /// `trace` must have the same `neurons × batch` geometry. Values are
    /// bit-identical to [`LifLayer::step_masked`] followed by a masked
    /// trace update with this step's spikes. Returns the number of
    /// spikes emitted by active sessions.
    ///
    /// The lane loop is shaped for auto-vectorization (DESIGN.md
    /// §Hot-Path): bounds-check-free sub-slice zips over the ≤64
    /// contiguous session lanes of one word, with per-lane selects
    /// instead of branches.
    #[inline]
    pub fn step_trace_masked(
        &mut self,
        currents: &[S],
        trace: &mut TraceVector<S>,
        active_words: &[u64],
    ) -> usize {
        assert_eq!(currents.len(), self.v.len(), "current/neuron mismatch");
        assert_eq!(trace.values.len(), self.v.len(), "trace/neuron mismatch");
        assert_eq!(
            active_words.len(),
            self.spikes.words_per_row(),
            "mask/batch mismatch"
        );
        let b = self.batch;
        let lambda = trace.lambda;
        let v_th = self.v_th;
        let soft = self.soft_reset;
        let mut fired = 0usize;
        for i in 0..self.neurons {
            for (wi, &aw) in active_words.iter().enumerate() {
                if aw == 0 {
                    continue;
                }
                let lanes = (b - wi * LANES).min(LANES);
                let base = i * b + wi * LANES;
                let mut bits = self.spikes.row(i)[wi] & !aw;
                let vs = &mut self.v[base..base + lanes];
                let ts = &mut trace.values[base..base + lanes];
                let cs = &currents[base..base + lanes];
                for (l, ((v, t), &c)) in vs.iter_mut().zip(ts.iter_mut()).zip(cs).enumerate() {
                    let on = (aw >> l) & 1 == 1;
                    let old = *v;
                    let (stepped, fire) = lif_step_scalar(old, c, v_th, soft);
                    *v = if on { stepped } else { old };
                    bits |= ((on && fire) as u64) << l;
                    fired += (on && fire) as usize;
                    // Trace: S ← λ·S + s(t), the `trace_step_scalar`
                    // datapath with a masked select.
                    let t_old = *t;
                    let t_new = crate::snn::trace::trace_step_scalar(t_old, fire, lambda);
                    *t = if on { t_new } else { t_old };
                }
                self.spikes.row_mut(i)[wi] = bits;
            }
        }
        fired
    }
}

/// Scalar single-neuron step (used by the FPGA simulator's Neuron Dynamic
/// Unit, which processes one neuron per PE per cycle, and by the dense
/// scalar reference model in [`crate::snn::reference`]).
#[inline]
pub fn lif_step_scalar<S: Scalar>(v: S, i: S, v_th: S, soft_reset: bool) -> (S, bool) {
    let nv = v.half().add(i.half());
    if nv > v_th {
        let reset = if soft_reset { nv.sub(v_th) } else { S::ZERO };
        (reset, true)
    } else {
        (nv, false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::spike::{full_mask, mask_words};
    use crate::util::fp16::F16;

    #[test]
    fn integrates_toward_input() {
        let mut l = LifLayer::<f32>::new(1, 10.0); // high threshold: no spikes
        for _ in 0..64 {
            l.step(&[2.0]);
        }
        // Fixed point of V = V/2 + I/2 is I.
        assert!((l.v[0] - 2.0).abs() < 1e-4);
    }

    #[test]
    fn spikes_and_soft_resets() {
        let mut l = LifLayer::<f32>::new(1, 1.0);
        let mut spike_times = Vec::new();
        for t in 0..20 {
            let fired = l.step(&[4.0]);
            if fired > 0 {
                spike_times.push(t);
            }
        }
        assert!(!spike_times.is_empty());
        // With I=4: V converges to 4 > th, so after the first spike the
        // neuron fires regularly.
        assert!(spike_times.len() >= 10);
        // Soft reset keeps V positive after a spike with strong drive.
        assert!(l.v[0] > 0.0);
    }

    #[test]
    fn hard_reset_zeroes() {
        let mut l = LifLayer::<f32>::new(1, 1.0);
        l.soft_reset = false;
        // Drive hard for a few steps, check V==0 right after a spike step.
        let mut saw_spike = false;
        for _ in 0..10 {
            if l.step(&[10.0]) > 0 {
                assert_eq!(l.v[0], 0.0);
                saw_spike = true;
                break;
            }
        }
        assert!(saw_spike);
    }

    #[test]
    fn no_input_decays_to_zero() {
        let mut l = LifLayer::<f32>::new(1, 1.0);
        l.v[0] = 0.9;
        for _ in 0..40 {
            l.step(&[0.0]);
        }
        assert!(l.v[0].abs() < 1e-6);
    }

    #[test]
    fn f16_matches_f32_for_representable_values() {
        // Inputs chosen exactly representable in f16; the halving path is
        // exact, so both domains agree bit-for-bit here.
        let mut a = LifLayer::<f32>::new(1, 1.0);
        let mut b = LifLayer::<F16>::new(1, 1.0);
        for _ in 0..16 {
            a.step(&[0.5]);
            b.step(&[F16::from_f32(0.5)]);
            assert!((a.v[0] - b.v[0].to_f32()).abs() < 1e-3, "{} vs {}", a.v[0], b.v[0]);
            assert_eq!(a.spikes.get(0, 0), b.spikes.get(0, 0));
        }
    }

    #[test]
    fn scalar_step_equals_layer_step() {
        let mut l = LifLayer::<f32>::new(3, 1.0);
        let mut v = [0.0f32; 3];
        let currents = [0.7f32, 1.3, 2.9];
        for _ in 0..10 {
            l.step(&currents);
            for k in 0..3 {
                let (nv, sp) = lif_step_scalar(v[k], currents[k], 1.0, true);
                v[k] = nv;
                assert_eq!(sp, l.spikes.get(k, 0));
                assert!((v[k] - l.v[k]).abs() < 1e-6);
            }
        }
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn wrong_current_len_panics() {
        let mut l = LifLayer::<f32>::new(2, 1.0);
        l.step(&[1.0]);
    }

    #[test]
    fn batched_sessions_match_independent_layers() {
        // Three sessions with different drive levels, stepped batched,
        // must match three independent single-session layers bit-for-bit.
        let n = 4;
        let batch = 3;
        let drives = [0.7f32, 1.6, 3.2];
        let mut batched = LifLayer::<f32>::batched(n, batch, 1.0);
        let mut singles: Vec<LifLayer<f32>> = (0..batch).map(|_| LifLayer::new(n, 1.0)).collect();
        let active = full_mask(batch);
        for _ in 0..25 {
            let mut currents = vec![0.0f32; n * batch];
            for i in 0..n {
                for b in 0..batch {
                    currents[i * batch + b] = drives[b] + i as f32 * 0.1;
                }
            }
            batched.step_masked(&currents, &active);
            for (b, single) in singles.iter_mut().enumerate() {
                let cur: Vec<f32> = (0..n).map(|i| currents[i * batch + b]).collect();
                single.step(&cur);
                for i in 0..n {
                    assert_eq!(batched.v[i * batch + b], single.v[i], "v mismatch s{b} n{i}");
                    assert_eq!(batched.spikes.get(i, b), single.spikes.get(i, 0));
                }
            }
        }
    }

    #[test]
    fn fused_step_trace_matches_separate_passes() {
        let n = 5;
        let batch = 2;
        let active = mask_words(&[true, true]);
        let mut fused = LifLayer::<f32>::batched(n, batch, 1.0);
        let mut fused_tr = TraceVector::<f32>::batched(n, batch, 0.5);
        let mut sep = LifLayer::<f32>::batched(n, batch, 1.0);
        let mut sep_tr = TraceVector::<f32>::batched(n, batch, 0.5);
        for t in 0..30 {
            let currents: Vec<f32> = (0..n * batch)
                .map(|k| ((k + t) % 5) as f32 * 0.8)
                .collect();
            fused.step_trace_masked(&currents, &mut fused_tr, &active);
            sep.step_masked(&currents, &active);
            sep_tr.update_packed(&sep.spikes, &active);
            assert_eq!(fused.v, sep.v);
            assert_eq!(fused.spikes, sep.spikes);
            assert_eq!(fused_tr.values, sep_tr.values);
        }
    }

    #[test]
    fn masked_sessions_are_frozen() {
        let n = 2;
        let mut l = LifLayer::<f32>::batched(n, 2, 1.0);
        let currents = vec![4.0f32; n * 2];
        let only0 = mask_words(&[true, false]);
        // advance only session 0; session 1 must stay at zero state
        l.step_masked(&currents, &only0);
        l.step_masked(&currents, &only0);
        for i in 0..n {
            assert!(l.v[i * 2] != 0.0 || l.spikes.get(i, 0));
            assert_eq!(l.v[i * 2 + 1], 0.0);
            assert!(!l.spikes.get(i, 1));
        }
        // reset_session clears only the requested column
        l.reset_session(0);
        for i in 0..n {
            assert_eq!(l.v[i * 2], 0.0);
            assert!(!l.spikes.get(i, 0));
        }
    }

    #[test]
    fn grow_batch_preserves_sessions() {
        let n = 3;
        let mut l = LifLayer::<f32>::batched(n, 2, 1.0);
        let active = full_mask(2);
        let currents = vec![0.9f32; n * 2];
        l.step_masked(&currents, &active);
        let v_before: Vec<f32> = (0..n).map(|i| l.v[i * 2]).collect();
        let s_before: Vec<bool> = (0..n).map(|i| l.spikes.get(i, 0)).collect();
        l.grow_batch(70);
        assert_eq!(l.batch, 70);
        for i in 0..n {
            assert_eq!(l.v[i * 70], v_before[i]);
            assert_eq!(l.spikes.get(i, 0), s_before[i]);
            assert_eq!(l.v[i * 70 + 69], 0.0, "new session must start at rest");
            assert!(!l.spikes.get(i, 69));
        }
    }
}
