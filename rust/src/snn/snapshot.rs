//! Durable serving-state snapshots of a sharded network (ISSUE 10
//! tentpole; DESIGN.md §Durability-and-Faults).
//!
//! Serializes the **complete dynamic state** of a [`ShardedNetwork`] —
//! per-session plastic weights, membrane lanes, packed spike words,
//! trace lanes (including the lazy-decay clocks), step counters, the
//! runtime plasticity gate, and the deployed rule θ — into a
//! checksummed [`binio`](crate::util::binio) frame, and restores it
//! bit-exactly into a freshly constructed network of the same geometry.
//!
//! Scalar lanes travel as `u32` bit patterns
//! ([`Scalar::bit_pattern`] / [`Scalar::from_bit_pattern`]) so the
//! codec is one implementation across all three precisions (f32,
//! binary16, Q5.10) and round-trips are bit-exact by construction.
//!
//! Decoding is *total* and defensive: every length is validated against
//! the live network before any state is touched, a precision/geometry/
//! θ mismatch is a typed [`BinError::Malformed`] (the serving recovery
//! path treats it as "rejected: serve fresh", distinct from a corrupt
//! file, which is quarantined), and restore is **not transactional** —
//! on error the caller must reset the network before serving.
//!
//! Encoding appends frames in place through
//! [`BinWriter::begin_frame`] / [`BinWriter::seal_frame`], so on a
//! warm double-buffered `Vec` the serving stepper re-encodes a
//! snapshot with **zero heap allocations** (pinned by
//! `tests/alloc_free_serving.rs`).

use crate::snn::{spike, Scalar, ShardedNetwork, SnnNetwork};
use crate::util::binio::{BinError, BinReader, BinWriter};

/// Frame kind of one backend's full session-state blob ("SS").
pub const SESSION_STATE_FRAME_KIND: u16 = 0x5353;

/// Frame kind of one shard's state within a session-state blob ("SH").
pub const SHARD_FRAME_KIND: u16 = 0x5348;

/// Append one scalar lane vector as `u32` bit patterns (length-prefixed,
/// identical bytes to `put_u32s` — but loops over the scalars directly
/// so the hot encode path never materializes a temporary `Vec<u32>`).
fn put_lanes<S: Scalar>(w: &mut BinWriter, xs: &[S]) {
    w.put_usize(xs.len());
    for x in xs {
        w.put_u32(x.bit_pattern());
    }
}

/// Read a scalar lane vector written by [`put_lanes`] directly into
/// `dst`, rejecting a length mismatch before any element is written.
fn read_lanes_into<S: Scalar>(
    r: &mut BinReader<'_>,
    dst: &mut [S],
    what: &str,
) -> Result<(), BinError> {
    let n = r.get_len(4)?;
    if n != dst.len() {
        return Err(BinError::Malformed(format!(
            "{what}: {n} lanes in snapshot, {} live",
            dst.len()
        )));
    }
    for slot in dst.iter_mut() {
        *slot = S::from_bit_pattern(r.get_u32()?);
    }
    Ok(())
}

/// Read a `u64` vector, rejecting a length mismatch.
fn read_words(r: &mut BinReader<'_>, expect: usize, what: &str) -> Result<Vec<u64>, BinError> {
    let words = r.get_u64s()?;
    if words.len() != expect {
        return Err(BinError::Malformed(format!(
            "{what}: {} words in snapshot, {expect} live",
            words.len()
        )));
    }
    Ok(words)
}

/// Validate the packed-spike padding invariant: session lanes at or
/// beyond `batch` must be zero in every row's final word, or masked
/// stepping and trace accumulation would silently read ghost sessions.
fn check_padding(words: &[u64], batch: usize, what: &str) -> Result<(), BinError> {
    let tail = batch % 64;
    if tail == 0 {
        return Ok(());
    }
    let wpr = spike::words_for(batch);
    let mask = !0u64 << tail;
    for (row, chunk) in words.chunks(wpr).enumerate() {
        if chunk[wpr - 1] & mask != 0 {
            return Err(BinError::Malformed(format!(
                "{what}: nonzero padding lanes in row {row}"
            )));
        }
    }
    Ok(())
}

fn put_shard<S: Scalar>(w: &mut BinWriter, net: &SnnNetwork<S>) {
    let start = w.begin_frame(SHARD_FRAME_KIND);
    w.put_u64(net.steps);
    put_lanes(w, &net.w1);
    put_lanes(w, &net.w2);
    put_lanes(w, &net.hidden.v);
    w.put_u64s(net.hidden.spikes.words());
    put_lanes(w, &net.output.v);
    w.put_u64s(net.output.spikes.words());
    w.put_u64s(net.input().words());
    for trace in [&net.trace_in, &net.trace_hidden, &net.trace_out] {
        put_lanes(w, &trace.values);
        match trace.lazy_state() {
            Some((clock, last, hot)) => {
                w.put_bool(true);
                w.put_u64s(clock);
                w.put_u64s(last);
                w.put_u64s(hot);
            }
            None => w.put_bool(false),
        }
    }
    w.seal_frame(start);
}

fn read_shard<S: Scalar>(r: &mut BinReader<'_>, net: &mut SnnNetwork<S>) -> Result<(), BinError> {
    let mut r = r.get_frame(SHARD_FRAME_KIND)?;
    let batch = net.batch;
    net.steps = r.get_u64()?;
    read_lanes_into(&mut r, &mut net.w1, "w1")?;
    read_lanes_into(&mut r, &mut net.w2, "w2")?;
    read_lanes_into(&mut r, &mut net.hidden.v, "hidden.v")?;
    let words = read_words(&mut r, net.hidden.spikes.words().len(), "hidden spikes")?;
    check_padding(&words, batch, "hidden spikes")?;
    net.hidden.spikes.copy_words_from(&words);
    read_lanes_into(&mut r, &mut net.output.v, "output.v")?;
    let words = read_words(&mut r, net.output.spikes.words().len(), "output spikes")?;
    check_padding(&words, batch, "output spikes")?;
    net.output.spikes.copy_words_from(&words);
    let words = read_words(&mut r, net.input().words().len(), "input staging")?;
    check_padding(&words, batch, "input staging")?;
    net.input_mut().copy_words_from(&words);
    for (trace, what) in [
        (&mut net.trace_in, "trace_in"),
        (&mut net.trace_hidden, "trace_hidden"),
        (&mut net.trace_out, "trace_out"),
    ] {
        read_lanes_into(&mut r, &mut trace.values, what)?;
        let lazy_in_snap = r.get_bool()?;
        match (lazy_in_snap, trace.lazy_state().is_some()) {
            (true, true) => {
                let clock = read_words(&mut r, batch, &format!("{what} lazy clock"))?;
                let (_, last_live, hot_live) = trace.lazy_state().expect("checked lazy");
                let (n_last, n_hot) = (last_live.len(), hot_live.len());
                let last = read_words(&mut r, n_last, &format!("{what} lazy last"))?;
                let hot = read_words(&mut r, n_hot, &format!("{what} lazy hot"))?;
                trace.restore_lazy_state(&clock, &last, &hot);
            }
            (false, false) => {}
            (snap, _) => {
                return Err(BinError::Malformed(format!(
                    "{what}: snapshot is {} but live trace is {}",
                    if snap { "lazy" } else { "eager" },
                    if snap { "eager" } else { "lazy" },
                )))
            }
        }
    }
    r.finish()
}

/// Append the complete dynamic state of `net` to `w` as one
/// [`SESSION_STATE_FRAME_KIND`] frame. Allocation-free once `w`'s
/// buffer is warm.
pub fn encode_session_state<S: Scalar>(net: &ShardedNetwork<S>, w: &mut BinWriter) {
    let cfg = net.cfg();
    let start = w.begin_frame(SESSION_STATE_FRAME_KIND);
    w.put_u32(S::PREC_TAG as u32);
    w.put_usize(cfg.n_in);
    w.put_usize(cfg.n_hidden);
    w.put_usize(cfg.n_out);
    w.put_bool(cfg.plasticity.presyn_gate);
    w.put_usize(net.batch());
    w.put_usize(net.stripes());
    w.put_bool(net.plasticity_enabled());
    match net.rule() {
        Some(rule) => {
            w.put_u8(1);
            // θ travels inline (the deployed rule is part of the
            // session state), written field-by-field so the warm
            // encode path avoids `to_flat`'s temporary Vec.
            w.put_usize(rule.l1.theta.len() + rule.l2.theta.len());
            for &x in &rule.l1.theta {
                w.put_f32(x);
            }
            for &x in &rule.l2.theta {
                w.put_f32(x);
            }
        }
        None => w.put_u8(0),
    }
    for k in 0..net.shard_count() {
        put_shard(w, net.shard(k));
    }
    w.seal_frame(start);
}

/// Restore a [`SESSION_STATE_FRAME_KIND`] frame (read from `r` at the
/// cursor) into `net`, growing its batch if the snapshot carries more
/// sessions. The snapshot must match the live network's precision,
/// geometry, shard layout, and deployed θ bit-for-bit — any mismatch is
/// a typed [`BinError::Malformed`], which the serving recovery path
/// reports as "rejected" (stale deployment: serve fresh, don't
/// quarantine). **Not transactional**: on error the network may hold
/// partial state and must be reset before serving.
pub fn decode_session_state<S: Scalar>(
    net: &mut ShardedNetwork<S>,
    r: &mut BinReader<'_>,
) -> Result<(), BinError> {
    let mut r = r.get_frame(SESSION_STATE_FRAME_KIND)?;
    let tag = r.get_u32()?;
    if tag != S::PREC_TAG as u32 {
        return Err(BinError::Malformed(format!(
            "precision tag {tag:#06x} in snapshot, live backend is {:#06x}",
            S::PREC_TAG
        )));
    }
    let (n_in, n_hidden, n_out) = (r.get_usize()?, r.get_usize()?, r.get_usize()?);
    let cfg = net.cfg();
    if (n_in, n_hidden, n_out) != (cfg.n_in, cfg.n_hidden, cfg.n_out) {
        return Err(BinError::Malformed(format!(
            "geometry {n_in}x{n_hidden}x{n_out} in snapshot, live is {}x{}x{}",
            cfg.n_in, cfg.n_hidden, cfg.n_out
        )));
    }
    let presyn_gate = r.get_bool()?;
    if presyn_gate != cfg.plasticity.presyn_gate {
        return Err(BinError::Malformed(
            "presyn_gate (lazy-trace layout) differs from live config".into(),
        ));
    }
    let batch = r.get_usize()?;
    let stripes = r.get_usize()?;
    if stripes != net.stripes() {
        return Err(BinError::Malformed(format!(
            "{stripes} stripes in snapshot, live has {} (shard layout differs)",
            net.stripes()
        )));
    }
    if batch < net.batch() {
        return Err(BinError::Malformed(format!(
            "{batch} sessions in snapshot, live already has {} (batch only grows)",
            net.batch()
        )));
    }
    let plasticity_enabled = r.get_bool()?;
    match r.get_u8()? {
        1 => {
            let rule = net.rule().ok_or_else(|| {
                BinError::Malformed("plastic snapshot, live backend is fixed-weight".into())
            })?;
            let n = r.get_len(4)?;
            if n != rule.l1.theta.len() + rule.l2.theta.len() {
                return Err(BinError::Malformed(format!(
                    "rule theta length {n} differs from deployed rule"
                )));
            }
            for &live in rule.l1.theta.iter().chain(&rule.l2.theta) {
                if r.get_f32()?.to_bits() != live.to_bits() {
                    return Err(BinError::Malformed(
                        "rule theta differs bit-for-bit from deployed rule".into(),
                    ));
                }
            }
        }
        0 => {
            if net.rule().is_some() {
                return Err(BinError::Malformed(
                    "fixed-weight snapshot, live backend is plastic".into(),
                ));
            }
        }
        other => return Err(BinError::Malformed(format!("bad mode tag {other}"))),
    }
    if batch > net.batch() {
        net.grow_batch(batch);
    }
    for k in 0..net.shard_count() {
        read_shard(&mut r, net.shard_mut(k))?;
    }
    r.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::{Mode, NetworkRule, SnnConfig};
    use crate::util::fixed::Qfx;
    use crate::util::fp16::F16;
    use crate::util::rng::Pcg64;

    fn tiny_rule(cfg: &SnnConfig, seed: u64) -> NetworkRule {
        let mut rng = Pcg64::new(seed, 0);
        let mut flat = vec![0.0f32; cfg.n_rule_params()];
        rng.fill_normal_f32(&mut flat, 0.25);
        NetworkRule::from_flat(cfg, &flat)
    }

    fn drive<S: Scalar>(net: &mut ShardedNetwork<S>, seed: u64, ticks: usize) {
        let cfg = net.cfg().clone();
        let mut rng = Pcg64::new(seed, 1);
        let batch = net.batch();
        let mut spikes = vec![false; cfg.n_in];
        for _ in 0..ticks {
            net.begin_tick();
            for s in 0..batch {
                for b in spikes.iter_mut() {
                    *b = rng.bernoulli(0.5);
                }
                net.stage_session(s, &spikes);
            }
            net.step_staged();
        }
    }

    fn encode<S: Scalar>(net: &ShardedNetwork<S>) -> Vec<u8> {
        let mut w = BinWriter::new();
        encode_session_state(net, &mut w);
        w.into_bytes()
    }

    fn round_trip_case<S: Scalar>(lazy: bool, stripes: usize, batch: usize) {
        let mut cfg = SnnConfig::tiny();
        cfg.plasticity.presyn_gate = lazy;
        let rule = tiny_rule(&cfg, 0xA0);
        let mut live = ShardedNetwork::<S>::new(cfg.clone(), Mode::Plastic(rule.clone().into()), stripes);
        live.grow_batch(batch);
        drive(&mut live, 0xB0, 9);
        let bytes = encode(&live);

        let mut restored =
            ShardedNetwork::<S>::new(cfg.clone(), Mode::Plastic(rule.into()), stripes);
        decode_session_state(&mut restored, &mut BinReader::new(&bytes)).unwrap();
        assert_eq!(restored.batch(), batch);

        // Bit-identical re-encode, and bit-identical continuation.
        assert_eq!(encode(&restored), bytes, "re-encode differs");
        drive(&mut live, 0xC0, 7);
        drive(&mut restored, 0xC0, 7);
        assert_eq!(encode(&restored), encode(&live), "continuation diverged");
    }

    #[test]
    fn round_trips_bit_exactly_across_precisions_shards_and_trace_modes() {
        for &lazy in &[false, true] {
            for &(stripes, batch) in &[(1usize, 5usize), (2, 70), (4, 130)] {
                round_trip_case::<f32>(lazy, stripes, batch);
                round_trip_case::<F16>(lazy, stripes, batch);
                round_trip_case::<Qfx>(lazy, stripes, batch);
            }
        }
    }

    #[test]
    fn fixed_mode_round_trips() {
        let cfg = SnnConfig::tiny();
        let weights = vec![0.125f32; cfg.n_weights()];
        let mut live = ShardedNetwork::<f32>::new(cfg.clone(), Mode::Fixed, 1);
        live.load_weights(&weights);
        live.grow_batch(3);
        drive(&mut live, 7, 6);
        let bytes = encode(&live);
        let mut restored = ShardedNetwork::<f32>::new(cfg, Mode::Fixed, 1);
        restored.load_weights(&weights);
        decode_session_state(&mut restored, &mut BinReader::new(&bytes)).unwrap();
        assert_eq!(encode(&restored), bytes);
    }

    #[test]
    fn plasticity_gate_travels() {
        let cfg = SnnConfig::tiny();
        let rule = tiny_rule(&cfg, 0xD0);
        let mut live = ShardedNetwork::<f32>::new(cfg.clone(), Mode::Plastic(rule.clone().into()), 1);
        live.set_plasticity_enabled(false);
        let bytes = encode(&live);
        let mut restored = ShardedNetwork::<f32>::new(cfg, Mode::Plastic(rule.into()), 1);
        assert!(restored.plasticity_enabled());
        decode_session_state(&mut restored, &mut BinReader::new(&bytes)).unwrap();
        assert!(!restored.plasticity_enabled());
    }

    #[test]
    fn mismatches_are_typed_rejections() {
        let cfg = SnnConfig::tiny();
        let rule = tiny_rule(&cfg, 0xE0);
        let live = ShardedNetwork::<f32>::new(cfg.clone(), Mode::Plastic(rule.clone().into()), 1);
        let bytes = encode(&live);

        // Wrong precision.
        let mut f16 = ShardedNetwork::<F16>::new(cfg.clone(), Mode::Plastic(rule.clone().into()), 1);
        let err = decode_session_state(&mut f16, &mut BinReader::new(&bytes)).unwrap_err();
        assert!(matches!(err, BinError::Malformed(_)), "{err:?}");

        // Wrong geometry.
        let mut big_cfg = cfg.clone();
        big_cfg.n_hidden += 1;
        let big_rule = tiny_rule(&big_cfg, 0xE0);
        let mut big =
            ShardedNetwork::<f32>::new(big_cfg, Mode::Plastic(big_rule.into()), 1);
        let err = decode_session_state(&mut big, &mut BinReader::new(&bytes)).unwrap_err();
        assert!(matches!(err, BinError::Malformed(_)), "{err:?}");

        // Wrong shard layout.
        let mut striped = ShardedNetwork::<f32>::new(cfg.clone(), Mode::Plastic(rule.clone().into()), 2);
        striped.grow_batch(70);
        let err = decode_session_state(&mut striped, &mut BinReader::new(&bytes)).unwrap_err();
        assert!(matches!(err, BinError::Malformed(_)), "{err:?}");

        // Different deployed θ.
        let other_rule = tiny_rule(&cfg, 0xE1);
        let mut other =
            ShardedNetwork::<f32>::new(cfg.clone(), Mode::Plastic(other_rule.into()), 1);
        let err = decode_session_state(&mut other, &mut BinReader::new(&bytes)).unwrap_err();
        assert!(matches!(err, BinError::Malformed(_)), "{err:?}");

        // Fixed-vs-plastic mode clash.
        let mut fixed = ShardedNetwork::<f32>::new(cfg, Mode::Fixed, 1);
        let err = decode_session_state(&mut fixed, &mut BinReader::new(&bytes)).unwrap_err();
        assert!(matches!(err, BinError::Malformed(_)), "{err:?}");
    }

    #[test]
    fn corruption_and_truncation_are_typed_never_panic() {
        let cfg = SnnConfig::tiny();
        let rule = tiny_rule(&cfg, 0xF0);
        let mut live = ShardedNetwork::<f32>::new(cfg.clone(), Mode::Plastic(rule.clone().into()), 1);
        live.grow_batch(5);
        drive(&mut live, 0xF1, 5);
        let bytes = encode(&live);

        for cut in (0..bytes.len()).step_by(7) {
            let mut net = ShardedNetwork::<f32>::new(cfg.clone(), Mode::Plastic(rule.clone().into()), 1);
            assert!(
                decode_session_state(&mut net, &mut BinReader::new(&bytes[..cut])).is_err(),
                "cut at {cut} must not decode"
            );
        }
        for byte in (0..bytes.len()).step_by(11) {
            let mut bad = bytes.clone();
            bad[byte] ^= 0x40;
            let mut net = ShardedNetwork::<f32>::new(cfg.clone(), Mode::Plastic(rule.clone().into()), 1);
            assert!(
                decode_session_state(&mut net, &mut BinReader::new(&bad)).is_err(),
                "flip at {byte} must not decode"
            );
        }
    }

    #[test]
    fn nonzero_padding_lanes_are_rejected() {
        // batch 5 leaves 59 padding lanes per word; a snapshot that sets
        // one must be rejected, or ghost sessions would leak into masked
        // stepping after restore.
        let cfg = SnnConfig::tiny();
        let rule = tiny_rule(&cfg, 0x11);
        let mut live = ShardedNetwork::<f32>::new(cfg.clone(), Mode::Plastic(rule.clone().into()), 1);
        live.grow_batch(5);
        drive(&mut live, 0x12, 3);
        live.shard_mut(0).hidden.spikes.row_mut(0)[0] |= 1u64 << 63;
        let bytes = encode(&live);
        let mut net = ShardedNetwork::<f32>::new(cfg, Mode::Plastic(rule.into()), 1);
        let err = decode_session_state(&mut net, &mut BinReader::new(&bytes)).unwrap_err();
        assert!(
            matches!(&err, BinError::Malformed(m) if m.contains("padding")),
            "{err:?}"
        );
    }

    #[test]
    fn encode_into_warm_buffer_reuses_allocation() {
        let cfg = SnnConfig::tiny();
        let rule = tiny_rule(&cfg, 0x21);
        let mut live = ShardedNetwork::<f32>::new(cfg, Mode::Plastic(rule.into()), 1);
        live.grow_batch(8);
        drive(&mut live, 0x22, 4);
        let mut w = BinWriter::new();
        encode_session_state(&live, &mut w);
        let first = w.into_bytes();
        let cap = first.capacity();
        let ptr = first.as_ptr();
        let mut w = BinWriter::from_vec(first);
        encode_session_state(&live, &mut w);
        let second = w.into_bytes();
        assert_eq!(second.capacity(), cap);
        assert_eq!(second.as_ptr(), ptr, "warm re-encode must not reallocate");
    }
}
