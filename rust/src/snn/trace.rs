//! Exponentially decaying spike traces (§II-A):
//!
//! ```text
//! S(t) = λ·S(t−1) + s(t),   s(t) ∈ {0, 1}
//! ```
//!
//! The trace is the plasticity rule's only memory of past activity. The
//! default λ = 0.5 makes the decay a single halving — shift-friendly in
//! hardware (the Trace Update Unit shares the Forward Engine's
//! shift-and-add style) and exactly representable in FP16, so the
//! software golden model, the XLA artifact and the FPGA simulator agree
//! bit-for-bit on trace values for any spike history.
//!
//! # Lazy decay (DESIGN.md §Hot-Path)
//!
//! Eager trace maintenance multiplies **every** `(neuron, session)` lane
//! by λ **every** active tick, even when the population is almost
//! silent. A [`TraceVector`] constructed with [`TraceVector::batched_lazy`]
//! instead stores, per lane, the value at its last materialization plus
//! the per-session active-tick clock at that moment; decay is applied
//! **on read** as the pending `λ^Δ` product. Because each materialization
//! replays exactly the `Δ` per-step `mul(λ)` roundings the eager path
//! would have performed (see [`decay_steps`]), lazy and eager histories
//! are **bit-identical** in both f32 and FP16 — pinned by the property
//! suite in `tests/lazy_traces.rs`. A per-`(neuron, word)` **hot mask**
//! tracks which lanes hold a nonzero stored value: it is the lazy
//! machinery's own bookkeeping — [`TraceVector::materialize_hot`] walks
//! only hot lanes and retires drained ones, so fully silent rows cost
//! nothing per tick. The same mask doubles as the plasticity gate's
//! **row prefilter** ([`TraceVector::hot_rows`], consumed by
//! [`crate::snn::plasticity::apply_update_batch`]): after
//! materialization a cold lane is *exactly zero*, so `hot & active == 0`
//! proves a row sub-ε in one AND per word — the value scan only runs on
//! rows the prefilter could not dismiss, keeping the gate's skip
//! decisions bit-identical to the eager dense oracle's.

use super::numeric::Scalar;
use super::spike::{self, grow_lanes, SpikeWords, LANES};

/// Per-neuron exponentially decaying spike traces.
///
/// Like [`crate::snn::LifLayer`], the vector carries a
/// structure-of-arrays **batch dimension** (`[neuron][session]` layout)
/// so one trace update can serve many independent controller sessions;
/// `batch == 1` reproduces the historical single-session layout exactly.
/// Batched updates consume bit-packed [`SpikeWords`] (DESIGN.md
/// §Hot-Path), and on the serving path the update is fused into the LIF
/// sweep via [`crate::snn::LifLayer::step_trace_masked`].
#[derive(Clone, Debug)]
pub struct TraceVector<S: Scalar> {
    /// Trace values, `neurons × batch`, laid out `[neuron][session]`.
    /// In lazy mode a lane's stored value is *stale*: it reflects the
    /// lane's last materialization, with `clock − last` decay steps
    /// still pending.
    pub values: Vec<S>,
    /// Decay factor λ applied every step before spike accumulation.
    pub lambda: S,
    /// Number of independent sessions interleaved in `values`.
    pub batch: usize,
    /// Number of neurons traced (`values.len() == neurons * batch`).
    pub neurons: usize,
    /// Lazy-decay mode flag (set by [`TraceVector::batched_lazy`]).
    lazy: bool,
    /// Lazy only: per-session count of active ticks elapsed
    /// ([`TraceVector::tick`]). Length `batch`.
    clock: Vec<u64>,
    /// Lazy only: per-lane clock value at the lane's last
    /// materialization. Same `[neuron][session]` indexing as `values`.
    last: Vec<u64>,
    /// Lazy only: per-`(neuron, word)` bitmask of lanes whose stored
    /// value is nonzero — the active-presynaptic set. Layout mirrors
    /// [`SpikeWords`]: `neurons × words_for(batch)`.
    hot: Vec<u64>,
}

impl<S: Scalar> TraceVector<S> {
    /// Single-session trace vector over `n` neurons.
    pub fn new(n: usize, lambda: f32) -> Self {
        Self::batched(n, 1, lambda)
    }

    /// Trace vector over `n` neurons × `batch` independent sessions.
    pub fn batched(n: usize, batch: usize, lambda: f32) -> Self {
        assert!((0.0..=1.0).contains(&lambda), "λ must be in [0,1]");
        assert!(batch >= 1, "batch must be >= 1");
        TraceVector {
            values: vec![S::ZERO; n * batch],
            lambda: S::from_f32(lambda),
            batch,
            neurons: n,
            lazy: false,
            clock: Vec::new(),
            last: Vec::new(),
            hot: Vec::new(),
        }
    }

    /// Lazy-decay trace vector (see the module docs): decay is deferred
    /// per lane and applied on spike arrival or on explicit
    /// materialization, bit-identically to the eager path. The eager
    /// update entry points ([`TraceVector::update`] /
    /// [`TraceVector::update_packed`]) must not be called on a lazy
    /// vector; drive it with [`TraceVector::tick`] +
    /// [`TraceVector::record_spikes_packed`] +
    /// [`TraceVector::materialize_hot`] instead.
    pub fn batched_lazy(n: usize, batch: usize, lambda: f32) -> Self {
        let mut t = Self::batched(n, batch, lambda);
        t.lazy = true;
        t.clock = vec![0; batch];
        t.last = vec![0; n * batch];
        t.hot = vec![0; n * spike::words_for(batch)];
        t
    }

    /// Whether this vector defers decay (constructed via
    /// [`TraceVector::batched_lazy`]).
    #[inline]
    pub fn is_lazy(&self) -> bool {
        self.lazy
    }

    /// Total state size (`neurons × batch`).
    pub fn len(&self) -> usize {
        self.values.len()
    }

    /// True when the vector traces no neurons.
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Zero all traces (every session).
    pub fn reset(&mut self) {
        for v in self.values.iter_mut() {
            *v = S::ZERO;
        }
        if self.lazy {
            self.clock.iter_mut().for_each(|c| *c = 0);
            self.last.iter_mut().for_each(|l| *l = 0);
            self.hot.iter_mut().for_each(|h| *h = 0);
        }
    }

    /// Zero one session's trace column, leaving other sessions untouched.
    pub fn reset_session(&mut self, session: usize) {
        assert!(session < self.batch, "session out of range");
        for i in 0..self.neurons {
            self.values[i * self.batch + session] = S::ZERO;
        }
        if self.lazy {
            let now = self.clock[session];
            let wpr = spike::words_for(self.batch);
            let bit = !(1u64 << (session % LANES));
            for i in 0..self.neurons {
                self.last[i * self.batch + session] = now;
                self.hot[i * wpr + session / LANES] &= bit;
            }
        }
    }

    /// Grow the session dimension to `new_batch`, preserving every
    /// existing session's trace values; new sessions start at zero.
    pub fn grow_batch(&mut self, new_batch: usize) {
        assert!(new_batch >= self.batch, "batch can only grow");
        if new_batch == self.batch {
            return;
        }
        self.values = grow_lanes(&self.values, self.batch, new_batch, S::ZERO);
        if self.lazy {
            self.last = grow_lanes(&self.last, self.batch, new_batch, 0u64);
            self.clock.resize(new_batch, 0);
            // Re-lay the hot masks to the wider word rows (lane bit
            // positions are stable under growth, like SpikeWords).
            let old_wpr = spike::words_for(self.batch);
            let new_wpr = spike::words_for(new_batch);
            let mut hot = vec![0u64; self.neurons * new_wpr];
            for n in 0..self.neurons {
                hot[n * new_wpr..n * new_wpr + old_wpr]
                    .copy_from_slice(&self.hot[n * old_wpr..(n + 1) * old_wpr]);
            }
            self.hot = hot;
        }
        self.batch = new_batch;
    }

    /// Decay all traces and add the new spike indicators (dense boolean
    /// form, every session; the reference/compat path).
    pub fn update(&mut self, spikes: &[bool]) {
        assert!(!self.lazy, "eager update on a lazy TraceVector");
        assert_eq!(spikes.len(), self.values.len(), "spike/trace mismatch");
        for (v, &s) in self.values.iter_mut().zip(spikes) {
            let decayed = v.mul(self.lambda);
            *v = if s { decayed.add(S::ONE) } else { decayed };
        }
    }

    /// Batched update from bit-packed spike words over the sessions
    /// selected by the packed `active_words` mask; inactive sessions'
    /// traces are left untouched (branch-free lane selects). Per-session
    /// arithmetic matches [`TraceVector::update`] exactly, so batched and
    /// single-session trace histories are bit-identical.
    pub fn update_packed(&mut self, spikes: &SpikeWords, active_words: &[u64]) {
        assert!(!self.lazy, "eager update on a lazy TraceVector");
        assert_eq!(spikes.neurons(), self.neurons, "spike/trace mismatch");
        assert_eq!(spikes.batch(), self.batch, "spike/trace batch mismatch");
        assert_eq!(
            active_words.len(),
            spikes.words_per_row(),
            "mask/batch mismatch"
        );
        let b = self.batch;
        for i in 0..self.neurons {
            let row = spikes.row(i);
            for (wi, &aw) in active_words.iter().enumerate() {
                if aw == 0 {
                    continue;
                }
                let bits = row[wi];
                let lanes = (b - wi * LANES).min(LANES);
                let base = i * b + wi * LANES;
                for l in 0..lanes {
                    let on = (aw >> l) & 1 == 1;
                    let idx = base + l;
                    let old = self.values[idx];
                    let new = trace_step_scalar(old, (bits >> l) & 1 == 1, self.lambda);
                    self.values[idx] = if on { new } else { old };
                }
            }
        }
    }

    /// Steady-state value for a neuron spiking every step: 1/(1−λ).
    pub fn saturation(&self) -> f32 {
        1.0 / (1.0 - self.lambda.to_f32())
    }

    // --- lazy-decay entry points (DESIGN.md §Hot-Path) ---------------

    /// Lazy mode: advance the active-tick clock of every session whose
    /// bit is set in `active_words`. One call per network step, **before**
    /// [`TraceVector::record_spikes_packed`]; cost is O(active sessions),
    /// no trace lane is touched.
    pub fn tick(&mut self, active_words: &[u64]) {
        assert!(self.lazy, "tick on an eager TraceVector");
        assert_eq!(active_words.len(), spike::words_for(self.batch), "mask/batch mismatch");
        for (wi, &aw) in active_words.iter().enumerate() {
            for l in spike::set_bits(aw) {
                self.clock[wi * LANES + l] += 1;
            }
        }
    }

    /// Lazy mode: fold this tick's spikes into the traces. For every set
    /// bit of `spikes & active_words` the lane is materialized (pending
    /// `λ^Δ` decay applied with per-step rounding) and incremented by
    /// one — exactly the `trace_step_scalar` history the eager path
    /// would have produced. Silent lanes are left stale. Cost is
    /// O(spikes), not O(neurons × batch). Call after
    /// [`TraceVector::tick`].
    pub fn record_spikes_packed(&mut self, spikes: &SpikeWords, active_words: &[u64]) {
        assert!(self.lazy, "record_spikes_packed on an eager TraceVector");
        assert_eq!(spikes.neurons(), self.neurons, "spike/trace mismatch");
        assert_eq!(spikes.batch(), self.batch, "spike/trace batch mismatch");
        assert_eq!(active_words.len(), spikes.words_per_row(), "mask/batch mismatch");
        let b = self.batch;
        let wpr = spikes.words_per_row();
        for i in 0..self.neurons {
            let row = spikes.row(i);
            for (wi, &aw) in active_words.iter().enumerate() {
                let m = row[wi] & aw;
                if m == 0 {
                    continue;
                }
                for l in spike::set_bits(m) {
                    let lane = wi * LANES + l;
                    let idx = i * b + lane;
                    let pending = self.clock[lane] - self.last[idx];
                    let decayed = decay_steps(self.values[idx], self.lambda, pending);
                    self.values[idx] = decayed.add(S::ONE);
                    self.last[idx] = self.clock[lane];
                }
                self.hot[i * wpr + wi] |= m;
            }
        }
    }

    /// Lazy mode: bring every hot lane up to date (apply its pending
    /// decay), clearing the hot bit of lanes that drained to exactly
    /// zero. After this call, `values` of hot rows equal the eager
    /// path's bit-for-bit; cold rows are all-zero by invariant. Cost is
    /// O(hot lanes). Returns the number of rows with at least one hot
    /// lane remaining.
    pub fn materialize_hot(&mut self) -> usize {
        assert!(self.lazy, "materialize_hot on an eager TraceVector");
        let b = self.batch;
        let wpr = spike::words_for(b);
        let mut hot_rows = 0usize;
        for i in 0..self.neurons {
            let mut row_hot = 0u64;
            for wi in 0..wpr {
                let hw = self.hot[i * wpr + wi];
                if hw == 0 {
                    continue;
                }
                let mut keep = hw;
                for l in spike::set_bits(hw) {
                    let lane = wi * LANES + l;
                    let idx = i * b + lane;
                    let pending = self.clock[lane] - self.last[idx];
                    if pending > 0 {
                        self.values[idx] = decay_steps(self.values[idx], self.lambda, pending);
                        self.last[idx] = self.clock[lane];
                    }
                    if self.values[idx] == S::ZERO {
                        keep &= !(1u64 << l);
                    }
                }
                self.hot[i * wpr + wi] = keep;
                row_hot |= keep;
            }
            hot_rows += (row_hot != 0) as usize;
        }
        hot_rows
    }

    /// Lazy mode: current (fully decayed) value of one lane, without
    /// mutating stored state — the "on-read `decay^Δ` materialization"
    /// view.
    pub fn value(&self, neuron: usize, session: usize) -> S {
        assert!(neuron < self.neurons && session < self.batch, "trace index out of range");
        let idx = neuron * self.batch + session;
        if !self.lazy {
            return self.values[idx];
        }
        let pending = self.clock[session] - self.last[idx];
        decay_steps(self.values[idx], self.lambda, pending)
    }

    /// Lazy mode: hot-lane mask of one `(neuron, word)` cell — the
    /// active-presynaptic set the lazy machinery maintains (which lanes
    /// [`TraceVector::materialize_hot`] must visit). Bits may be
    /// conservatively stale-hot until the next materialization clears
    /// drained lanes. Exposed for diagnostics and the invariant tests;
    /// the plasticity gate consumes the whole-row view
    /// ([`TraceVector::hot_rows`]) instead.
    #[inline]
    pub fn hot_word(&self, neuron: usize, word: usize) -> u64 {
        debug_assert!(self.lazy, "hot_word on an eager TraceVector");
        self.hot[neuron * spike::words_for(self.batch) + word]
    }

    /// Lazy mode: the full per-`(neuron, word)` hot-lane mask table
    /// (`neurons × words_for(batch)`, row-major) — the plasticity gate's
    /// row prefilter (see the module docs). Immediately after
    /// [`TraceVector::materialize_hot`] the masks are exact: a clear bit
    /// means that lane's stored value is exactly zero, so
    /// `hot_row & active == 0` proves every active lane of the row sub-ε
    /// without reading a single trace value.
    #[inline]
    pub fn hot_rows(&self) -> &[u64] {
        debug_assert!(self.lazy, "hot_rows on an eager TraceVector");
        &self.hot
    }

    /// Lazy bookkeeping as `(clock, last, hot)` slices, or `None` for an
    /// eager vector — the serialization view used by serving snapshots.
    /// The stored `values` are *stale* in lazy mode; a snapshot must
    /// carry all three arrays alongside them to reproduce the deferred
    /// decay bit-for-bit.
    pub fn lazy_state(&self) -> Option<(&[u64], &[u64], &[u64])> {
        if self.lazy {
            Some((&self.clock, &self.last, &self.hot))
        } else {
            None
        }
    }

    /// Restore lazy bookkeeping captured by [`TraceVector::lazy_state`]
    /// at the same `(neurons, batch)` geometry. Panics if the vector is
    /// eager or any array length mismatches — callers validate geometry
    /// through the snapshot's typed decode before reaching here.
    pub fn restore_lazy_state(&mut self, clock: &[u64], last: &[u64], hot: &[u64]) {
        assert!(self.lazy, "restore_lazy_state on an eager TraceVector");
        assert_eq!(clock.len(), self.clock.len(), "lazy clock length mismatch");
        assert_eq!(last.len(), self.last.len(), "lazy last length mismatch");
        assert_eq!(hot.len(), self.hot.len(), "lazy hot-mask length mismatch");
        self.clock.copy_from_slice(clock);
        self.last.copy_from_slice(last);
        self.hot.copy_from_slice(hot);
    }
}

/// Apply `steps` sequential λ-multiplies with the scalar domain's
/// per-step rounding — the exact operation sequence the eager path
/// performs, so lazy materialization is bit-identical to eager decay in
/// both f32 and FP16. Exits early at the decay fixed point (zero, or a
/// value λ can no longer shrink under rounding — e.g. λ = 1, or sticky
/// subnormals under RNE), which bounds the loop at the format's decay
/// horizon (≈ 26 steps for FP16 at λ = 0.5, ≈ 151 for f32) regardless
/// of how long a lane sat silent.
#[inline]
pub fn decay_steps<S: Scalar>(mut v: S, lambda: S, steps: u64) -> S {
    for _ in 0..steps {
        let nv = v.mul(lambda);
        if nv == v {
            return nv; // fixed point: every further step is identity
        }
        v = nv;
    }
    v
}

/// Scalar trace update used by the FPGA simulator's Trace Update Unit
/// and the dense scalar reference model.
#[inline]
pub fn trace_step_scalar<S: Scalar>(trace: S, spike: bool, lambda: S) -> S {
    let d = trace.mul(lambda);
    if spike {
        d.add(S::ONE)
    } else {
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::spike::mask_words;
    use crate::util::fp16::F16;

    #[test]
    fn no_spikes_decay_geometrically() {
        let mut t = TraceVector::<f32>::new(1, 0.5);
        t.values[0] = 1.0;
        let none = [false];
        t.update(&none);
        assert_eq!(t.values[0], 0.5);
        t.update(&none);
        assert_eq!(t.values[0], 0.25);
    }

    #[test]
    fn spike_adds_one() {
        let mut t = TraceVector::<f32>::new(1, 0.5);
        t.update(&[true]);
        assert_eq!(t.values[0], 1.0);
        t.update(&[true]);
        assert_eq!(t.values[0], 1.5);
    }

    #[test]
    fn saturates_at_one_over_one_minus_lambda() {
        let mut t = TraceVector::<f32>::new(1, 0.5);
        for _ in 0..64 {
            t.update(&[true]);
        }
        assert!((t.values[0] - t.saturation()).abs() < 1e-5);
        assert_eq!(t.saturation(), 2.0);
    }

    #[test]
    fn f16_bit_exact_with_lambda_half() {
        // λ=0.5 halving + +1.0 are exact in binary16 up to the format's
        // precision at the running magnitude, and the trace stays ≤ 2.0,
        // comfortably inside f16's exact dyadic range for this pattern.
        let mut a = TraceVector::<f32>::new(1, 0.5);
        let mut b = TraceVector::<F16>::new(1, 0.5);
        let mut rngish = 0x12345u32;
        for _ in 0..100 {
            rngish = rngish.wrapping_mul(1664525).wrapping_add(1013904223);
            let s = rngish & 1 == 0;
            a.update(&[s]);
            b.update(&[s]);
            // After a few steps the f32 value has more low bits than f16
            // keeps; check agreement to f16 resolution instead of equality.
            assert!(
                (a.values[0] - b.values[0].to_f32()).abs() <= 2e-3,
                "{} vs {}",
                a.values[0],
                b.values[0]
            );
        }
    }

    #[test]
    fn scalar_matches_vector() {
        let mut t = TraceVector::<f32>::new(1, 0.7);
        let mut s = 0.0f32;
        let pattern = [true, false, true, true, false, false, true];
        for &sp in &pattern {
            t.update(&[sp]);
            s = trace_step_scalar(s, sp, 0.7);
            assert!((t.values[0] - s).abs() < 1e-6);
        }
    }

    #[test]
    #[should_panic(expected = "λ")]
    fn invalid_lambda_panics() {
        TraceVector::<f32>::new(1, 1.5);
    }

    #[test]
    fn packed_update_matches_singles_and_respects_mask() {
        let n = 3;
        let batch = 2;
        let mut t = TraceVector::<f32>::batched(n, batch, 0.5);
        let mut s0 = TraceVector::<f32>::new(n, 0.5);
        let active = mask_words(&[true, false]);
        let patterns = [
            [true, false, true, true, false, false],
            [false, true, true, false, true, false],
        ];
        let mut packed = SpikeWords::new(n, batch);
        for row in &patterns {
            // spikes laid out [neuron][session]; session 1 masked off
            packed.fill_from_bools(row);
            t.update_packed(&packed, &active);
            let single: Vec<bool> = (0..n).map(|i| row[i * batch]).collect();
            s0.update(&single);
        }
        for i in 0..n {
            assert_eq!(t.values[i * batch], s0.values[i]);
            assert_eq!(t.values[i * batch + 1], 0.0, "masked session must stay zero");
        }
        t.reset_session(0);
        for i in 0..n {
            assert_eq!(t.values[i * batch], 0.0);
        }
    }

    #[test]
    fn lazy_matches_eager_bit_for_bit() {
        // Deterministic pin (the full property sweep over random
        // schedules, masks and FP16 lives in tests/lazy_traces.rs).
        let n = 4;
        let batch = 3;
        let mut eager = TraceVector::<f32>::batched(n, batch, 0.5);
        let mut lazy = TraceVector::<f32>::batched_lazy(n, batch, 0.5);
        assert!(lazy.is_lazy() && !eager.is_lazy());
        let mut packed = SpikeWords::new(n, batch);
        let mut x = 0x9E3779B9u64;
        for step in 0..200 {
            let active: Vec<bool> = (0..batch).map(|b| (step + b) % 4 != 0).collect();
            let mask = mask_words(&active);
            let mut dense = vec![false; n * batch];
            for d in dense.iter_mut() {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                *d = x >> 61 == 0; // ~12.5 % firing with long silent runs
            }
            packed.fill_from_bools(&dense);
            eager.update_packed(&packed, &mask);
            lazy.tick(&mask);
            lazy.record_spikes_packed(&packed, &mask);
            // on-read view agrees without materializing stored state
            for i in 0..n {
                for b in 0..batch {
                    assert_eq!(
                        lazy.value(i, b).to_bits(),
                        eager.values[i * batch + b].to_bits(),
                        "step {step} lane ({i},{b})"
                    );
                }
            }
        }
        // materialization writes the same bits into storage
        lazy.materialize_hot();
        for (l, e) in lazy.values.iter().zip(&eager.values) {
            assert_eq!(l.to_bits(), e.to_bits());
        }
    }

    #[test]
    fn lazy_long_silent_gap_underflows_to_zero_and_goes_cold() {
        let mut lazy = TraceVector::<F16>::batched_lazy(1, 1, 0.5);
        let mask = mask_words(&[true]);
        let mut packed = SpikeWords::new(1, 1);
        packed.fill_from_bools(&[true]);
        lazy.tick(&mask);
        lazy.record_spikes_packed(&packed, &mask);
        assert_eq!(lazy.materialize_hot(), 1, "spiked lane is hot");
        assert_eq!(lazy.value(0, 0).to_f32(), 1.0);
        // a long silent run: FP16 at λ=0.5 underflows to exactly zero
        // within ~26 steps; the hot bit must retire with it
        packed.clear();
        for _ in 0..100 {
            lazy.tick(&mask);
            lazy.record_spikes_packed(&packed, &mask);
        }
        assert_eq!(lazy.value(0, 0).to_f32(), 0.0);
        assert_eq!(lazy.materialize_hot(), 0, "drained lane must go cold");
        assert_eq!(lazy.hot_word(0, 0), 0);
        // and an eager twin agrees it is exactly zero
        let mut eager = TraceVector::<F16>::batched(1, 1, 0.5);
        eager.update(&[true]);
        for _ in 0..100 {
            eager.update(&[false]);
        }
        assert_eq!(eager.values[0].to_f32(), 0.0);
    }

    #[test]
    fn decay_steps_fixed_point_terminates() {
        // λ = 1 is an immediate fixed point: a huge pending gap must not
        // loop for its full length.
        let v = decay_steps(1.5f32, 1.0, u64::MAX);
        assert_eq!(v, 1.5);
        // λ = 0 collapses in one step
        assert_eq!(decay_steps(1.5f32, 0.0, u64::MAX), 0.0);
        // zero stays zero instantly
        assert_eq!(decay_steps(0.0f32, 0.5, u64::MAX), 0.0);
        // a normal value at λ=0.5 reaches exactly zero (f32 horizon)
        assert_eq!(decay_steps(2.0f32, 0.5, 200), 0.0);
    }

    #[test]
    fn lazy_inactive_sessions_do_not_decay() {
        let mut lazy = TraceVector::<f32>::batched_lazy(1, 2, 0.5);
        let mut packed = SpikeWords::new(1, 2);
        packed.fill_from_bools(&[true, true]);
        let both = mask_words(&[true, true]);
        lazy.tick(&both);
        lazy.record_spikes_packed(&packed, &both);
        // session 1 inactive for 3 ticks: its trace must stay at 1.0
        let only0 = mask_words(&[true, false]);
        packed.clear();
        for _ in 0..3 {
            lazy.tick(&only0);
            lazy.record_spikes_packed(&packed, &only0);
        }
        assert_eq!(lazy.value(0, 0), 0.125);
        assert_eq!(lazy.value(0, 1), 1.0, "inactive lane decayed");
    }

    #[test]
    fn lazy_reset_session_and_grow_batch() {
        let mut lazy = TraceVector::<f32>::batched_lazy(2, 2, 0.5);
        let mut packed = SpikeWords::new(2, 2);
        packed.fill_from_bools(&[true, true, false, true]);
        let both = mask_words(&[true, true]);
        lazy.tick(&both);
        lazy.record_spikes_packed(&packed, &both);
        lazy.reset_session(0);
        assert_eq!(lazy.value(0, 0), 0.0);
        assert_eq!(lazy.value(1, 0), 0.0);
        assert_eq!(lazy.value(0, 1), 1.0, "other session survives reset");
        lazy.grow_batch(70);
        assert_eq!(lazy.batch, 70);
        assert_eq!(lazy.value(0, 1), 1.0, "grow must preserve lanes");
        assert_eq!(lazy.value(0, 69), 0.0);
        // lane keeps decaying correctly after growth
        let mut active = vec![false; 70];
        active[1] = true;
        let mask = mask_words(&active);
        let mut grown = SpikeWords::new(2, 70);
        grown.clear();
        lazy.tick(&mask);
        lazy.record_spikes_packed(&grown, &mask);
        assert_eq!(lazy.value(0, 1), 0.5);
    }

    #[test]
    #[should_panic(expected = "eager update on a lazy TraceVector")]
    fn eager_update_on_lazy_panics() {
        let mut lazy = TraceVector::<f32>::batched_lazy(1, 1, 0.5);
        lazy.update(&[true]);
    }

    #[test]
    fn grow_batch_preserves_traces() {
        let mut t = TraceVector::<f32>::batched(2, 2, 0.5);
        t.values = vec![1.0, 2.0, 3.0, 4.0];
        t.grow_batch(65);
        assert_eq!(t.batch, 65);
        assert_eq!(t.values[0], 1.0);
        assert_eq!(t.values[1], 2.0);
        assert_eq!(t.values[65], 3.0);
        assert_eq!(t.values[66], 4.0);
        assert_eq!(t.values[64], 0.0);
    }
}
