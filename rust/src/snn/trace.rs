//! Exponentially decaying spike traces (§II-A):
//!
//! ```text
//! S(t) = λ·S(t−1) + s(t),   s(t) ∈ {0, 1}
//! ```
//!
//! The trace is the plasticity rule's only memory of past activity. The
//! default λ = 0.5 makes the decay a single halving — shift-friendly in
//! hardware (the Trace Update Unit shares the Forward Engine's
//! shift-and-add style) and exactly representable in FP16, so the
//! software golden model, the XLA artifact and the FPGA simulator agree
//! bit-for-bit on trace values for any spike history.

use super::numeric::Scalar;
use super::spike::{grow_lanes, SpikeWords, LANES};

/// Per-neuron exponentially decaying spike traces.
///
/// Like [`crate::snn::LifLayer`], the vector carries a
/// structure-of-arrays **batch dimension** (`[neuron][session]` layout)
/// so one trace update can serve many independent controller sessions;
/// `batch == 1` reproduces the historical single-session layout exactly.
/// Batched updates consume bit-packed [`SpikeWords`] (DESIGN.md
/// §Hot-Path), and on the serving path the update is fused into the LIF
/// sweep via [`crate::snn::LifLayer::step_trace_masked`].
#[derive(Clone, Debug)]
pub struct TraceVector<S: Scalar> {
    /// Trace values, `neurons × batch`, laid out `[neuron][session]`.
    pub values: Vec<S>,
    /// Decay factor λ applied every step before spike accumulation.
    pub lambda: S,
    /// Number of independent sessions interleaved in `values`.
    pub batch: usize,
    /// Number of neurons traced (`values.len() == neurons * batch`).
    pub neurons: usize,
}

impl<S: Scalar> TraceVector<S> {
    /// Single-session trace vector over `n` neurons.
    pub fn new(n: usize, lambda: f32) -> Self {
        Self::batched(n, 1, lambda)
    }

    /// Trace vector over `n` neurons × `batch` independent sessions.
    pub fn batched(n: usize, batch: usize, lambda: f32) -> Self {
        assert!((0.0..=1.0).contains(&lambda), "λ must be in [0,1]");
        assert!(batch >= 1, "batch must be >= 1");
        TraceVector {
            values: vec![S::ZERO; n * batch],
            lambda: S::from_f32(lambda),
            batch,
            neurons: n,
        }
    }

    /// Total state size (`neurons × batch`).
    pub fn len(&self) -> usize {
        self.values.len()
    }

    /// True when the vector traces no neurons.
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Zero all traces (every session).
    pub fn reset(&mut self) {
        for v in self.values.iter_mut() {
            *v = S::ZERO;
        }
    }

    /// Zero one session's trace column, leaving other sessions untouched.
    pub fn reset_session(&mut self, session: usize) {
        assert!(session < self.batch, "session out of range");
        for i in 0..self.neurons {
            self.values[i * self.batch + session] = S::ZERO;
        }
    }

    /// Grow the session dimension to `new_batch`, preserving every
    /// existing session's trace values; new sessions start at zero.
    pub fn grow_batch(&mut self, new_batch: usize) {
        assert!(new_batch >= self.batch, "batch can only grow");
        if new_batch == self.batch {
            return;
        }
        self.values = grow_lanes(&self.values, self.batch, new_batch, S::ZERO);
        self.batch = new_batch;
    }

    /// Decay all traces and add the new spike indicators (dense boolean
    /// form, every session; the reference/compat path).
    pub fn update(&mut self, spikes: &[bool]) {
        assert_eq!(spikes.len(), self.values.len(), "spike/trace mismatch");
        for (v, &s) in self.values.iter_mut().zip(spikes) {
            let decayed = v.mul(self.lambda);
            *v = if s { decayed.add(S::ONE) } else { decayed };
        }
    }

    /// Batched update from bit-packed spike words over the sessions
    /// selected by the packed `active_words` mask; inactive sessions'
    /// traces are left untouched (branch-free lane selects). Per-session
    /// arithmetic matches [`TraceVector::update`] exactly, so batched and
    /// single-session trace histories are bit-identical.
    pub fn update_packed(&mut self, spikes: &SpikeWords, active_words: &[u64]) {
        assert_eq!(spikes.neurons(), self.neurons, "spike/trace mismatch");
        assert_eq!(spikes.batch(), self.batch, "spike/trace batch mismatch");
        assert_eq!(
            active_words.len(),
            spikes.words_per_row(),
            "mask/batch mismatch"
        );
        let b = self.batch;
        for i in 0..self.neurons {
            let row = spikes.row(i);
            for (wi, &aw) in active_words.iter().enumerate() {
                if aw == 0 {
                    continue;
                }
                let bits = row[wi];
                let lanes = (b - wi * LANES).min(LANES);
                let base = i * b + wi * LANES;
                for l in 0..lanes {
                    let on = (aw >> l) & 1 == 1;
                    let idx = base + l;
                    let old = self.values[idx];
                    let new = trace_step_scalar(old, (bits >> l) & 1 == 1, self.lambda);
                    self.values[idx] = if on { new } else { old };
                }
            }
        }
    }

    /// Steady-state value for a neuron spiking every step: 1/(1−λ).
    pub fn saturation(&self) -> f32 {
        1.0 / (1.0 - self.lambda.to_f32())
    }
}

/// Scalar trace update used by the FPGA simulator's Trace Update Unit
/// and the dense scalar reference model.
#[inline]
pub fn trace_step_scalar<S: Scalar>(trace: S, spike: bool, lambda: S) -> S {
    let d = trace.mul(lambda);
    if spike {
        d.add(S::ONE)
    } else {
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::spike::mask_words;
    use crate::util::fp16::F16;

    #[test]
    fn no_spikes_decay_geometrically() {
        let mut t = TraceVector::<f32>::new(1, 0.5);
        t.values[0] = 1.0;
        let none = [false];
        t.update(&none);
        assert_eq!(t.values[0], 0.5);
        t.update(&none);
        assert_eq!(t.values[0], 0.25);
    }

    #[test]
    fn spike_adds_one() {
        let mut t = TraceVector::<f32>::new(1, 0.5);
        t.update(&[true]);
        assert_eq!(t.values[0], 1.0);
        t.update(&[true]);
        assert_eq!(t.values[0], 1.5);
    }

    #[test]
    fn saturates_at_one_over_one_minus_lambda() {
        let mut t = TraceVector::<f32>::new(1, 0.5);
        for _ in 0..64 {
            t.update(&[true]);
        }
        assert!((t.values[0] - t.saturation()).abs() < 1e-5);
        assert_eq!(t.saturation(), 2.0);
    }

    #[test]
    fn f16_bit_exact_with_lambda_half() {
        // λ=0.5 halving + +1.0 are exact in binary16 up to the format's
        // precision at the running magnitude, and the trace stays ≤ 2.0,
        // comfortably inside f16's exact dyadic range for this pattern.
        let mut a = TraceVector::<f32>::new(1, 0.5);
        let mut b = TraceVector::<F16>::new(1, 0.5);
        let mut rngish = 0x12345u32;
        for _ in 0..100 {
            rngish = rngish.wrapping_mul(1664525).wrapping_add(1013904223);
            let s = rngish & 1 == 0;
            a.update(&[s]);
            b.update(&[s]);
            // After a few steps the f32 value has more low bits than f16
            // keeps; check agreement to f16 resolution instead of equality.
            assert!(
                (a.values[0] - b.values[0].to_f32()).abs() <= 2e-3,
                "{} vs {}",
                a.values[0],
                b.values[0]
            );
        }
    }

    #[test]
    fn scalar_matches_vector() {
        let mut t = TraceVector::<f32>::new(1, 0.7);
        let mut s = 0.0f32;
        let pattern = [true, false, true, true, false, false, true];
        for &sp in &pattern {
            t.update(&[sp]);
            s = trace_step_scalar(s, sp, 0.7);
            assert!((t.values[0] - s).abs() < 1e-6);
        }
    }

    #[test]
    #[should_panic(expected = "λ")]
    fn invalid_lambda_panics() {
        TraceVector::<f32>::new(1, 1.5);
    }

    #[test]
    fn packed_update_matches_singles_and_respects_mask() {
        let n = 3;
        let batch = 2;
        let mut t = TraceVector::<f32>::batched(n, batch, 0.5);
        let mut s0 = TraceVector::<f32>::new(n, 0.5);
        let active = mask_words(&[true, false]);
        let patterns = [
            [true, false, true, true, false, false],
            [false, true, true, false, true, false],
        ];
        let mut packed = SpikeWords::new(n, batch);
        for row in &patterns {
            // spikes laid out [neuron][session]; session 1 masked off
            packed.fill_from_bools(row);
            t.update_packed(&packed, &active);
            let single: Vec<bool> = (0..n).map(|i| row[i * batch]).collect();
            s0.update(&single);
        }
        for i in 0..n {
            assert_eq!(t.values[i * batch], s0.values[i]);
            assert_eq!(t.values[i * batch + 1], 0.0, "masked session must stay zero");
        }
        t.reset_session(0);
        for i in 0..n {
            assert_eq!(t.values[i * batch], 0.0);
        }
    }

    #[test]
    fn grow_batch_preserves_traces() {
        let mut t = TraceVector::<f32>::batched(2, 2, 0.5);
        t.values = vec![1.0, 2.0, 3.0, 4.0];
        t.grow_batch(65);
        assert_eq!(t.batch, 65);
        assert_eq!(t.values[0], 1.0);
        assert_eq!(t.values[1], 2.0);
        assert_eq!(t.values[65], 3.0);
        assert_eq!(t.values[66], 4.0);
        assert_eq!(t.values[64], 0.0);
    }
}
