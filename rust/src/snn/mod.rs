//! SNN core: LIF dynamics, spike traces, the four-term plasticity rule,
//! and the three-layer controller network — the software golden model of
//! the computation FireFly-P performs (generic over f32 / bit-accurate
//! FP16, so the same code validates both the XLA artifact and the FPGA
//! simulator).
//!
//! Every stateful type carries a structure-of-arrays **batch dimension**
//! (`[element][session]` layout, batch = 1 by default) so one network
//! instance can step many independent controller sessions per tick —
//! the engine under the multi-session control server (DESIGN.md
//! §Batched-Serving). Sessions share the config and the frozen rule θ;
//! membranes, traces, and plastic weights are per-session.
//!
//! Binary spikes are carried as bit-packed `u64` session words
//! ([`spike::SpikeWords`]) so synaptic accumulation is event-driven —
//! work scales with the firing rate, not the synapse count — and masked
//! batched stepping is branch-free (DESIGN.md §Hot-Path). At serving
//! scale, [`shard::ShardedNetwork`] partitions the batch into 64-lane
//! word shards stepped in parallel across threadpool workers, and
//! event-driven plasticity ([`plasticity::PlasticityConfig::presyn_gate`]
//! + lazy traces in [`trace`]) makes the rule sweep scale with trace
//! sparsity too. The dense boolean formulation survives in [`reference`]
//! as the equivalence oracle.

pub mod encoding;
pub mod lif;
pub mod network;
pub mod numeric;
pub mod plasticity;
pub mod reference;
pub mod shard;
pub mod snapshot;
pub mod spike;
pub mod trace;

pub use lif::LifLayer;
pub use network::{Mode, NetworkRule, SnnConfig, SnnNetwork};
pub use numeric::Scalar;
pub use plasticity::{PlasticityConfig, RuleParams};
pub use shard::ShardedNetwork;
pub use spike::SpikeWords;
pub use trace::TraceVector;
