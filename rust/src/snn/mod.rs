//! SNN core: LIF dynamics, spike traces, the four-term plasticity rule,
//! and the three-layer controller network — the software golden model of
//! the computation FireFly-P performs (generic over f32 / bit-accurate
//! FP16, so the same code validates both the XLA artifact and the FPGA
//! simulator).

pub mod encoding;
pub mod lif;
pub mod network;
pub mod numeric;
pub mod plasticity;
pub mod trace;

pub use lif::LifLayer;
pub use network::{Mode, NetworkRule, SnnConfig, SnnNetwork};
pub use numeric::Scalar;
pub use plasticity::{PlasticityConfig, RuleParams};
pub use trace::TraceVector;
