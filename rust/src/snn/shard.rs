//! Multi-core sharded batched stepping (DESIGN.md §Hot-Path).
//!
//! One [`SnnNetwork`] steps its whole session batch on one thread. At
//! serving scale (hundreds of sessions) that single thread is the
//! throughput ceiling, so this module partitions the structure-of-arrays
//! batch into **64-lane word shards** — groups of whole packed spike
//! words — and drives each shard's step (forward, LIF/trace, plasticity)
//! on its own [`crate::util::threadpool::ThreadPool`] worker via the
//! pool's `scope`/`spawn_on` primitive. FireFly v2 calls the hardware
//! analogue *spatial parallelism*: independent lanes replicated across
//! compute cores.
//!
//! # Shard mapping
//!
//! A sharded network is built with a fixed **stripe count** `T`
//! (`--step-threads` on the serving CLI; default = CPU cores). Packed
//! word `w` (sessions `64w .. 64w+63`) belongs to shard `w % T`, and is
//! that shard's local word `w / T`. Session `s` therefore lives at
//!
//! ```text
//! shard  k = (s / 64) % T
//! lane   l = (s / 64) / T * 64 + s % 64
//! ```
//!
//! The modular assignment makes growth **migration-free**: growing the
//! batch only appends lanes to the globally-last word and appends new
//! words, and both only ever extend a shard's *own* lane tail
//! ([`SnnNetwork::grow_batch`] zero-fills it) — no session ever moves
//! between shards, so `ensure_sessions` can grow mid-serve without
//! copying live state across shard boundaries or leaving stale lane
//! data in remapped tails (regression-tested in
//! `tests/sharded_equivalence.rs`, 63 → 65 → 128 under load).
//!
//! # Equivalence
//!
//! Each shard is an ordinary [`SnnNetwork`] over its own sessions, and
//! sessions are mutually independent, so a sharded step is bit-identical
//! to the unsharded SoA step for every session — `T = 1` *is* the
//! unsharded path (same single `SnnNetwork`, stepped inline, no pool
//! dispatch, no allocation). Pinned by `tests/sharded_equivalence.rs`
//! at B ∈ {1, 64, 65, 256}.
//!
//! # Cost note
//!
//! Shards share nothing **mutable**; the frozen rule θ is shared
//! read-only behind `Arc<NetworkRule>` in [`Mode::Plastic`] — growing a
//! new shard clones the mode, which is an Arc refcount bump, so every
//! shard's plasticity sweep streams the *same* θ allocation (one copy
//! per process, reclaiming ~4 f32/synapse per extra shard versus the
//! pre-Arc per-shard replication; pinned by
//! `tests/sharded_equivalence.rs::shards_share_one_rule_theta`). Each
//! shard still amortizes that stream over up to 64 sessions per word,
//! and cross-core traffic stays read-only.

use std::sync::Arc;

use super::network::{Mode, NetworkRule, SnnConfig, SnnNetwork};
use super::numeric::Scalar;
use super::spike::{words_for, LANES};
use crate::util::threadpool::ThreadPool;

/// Where a session lives in the shard grid: `(shard index, local lane)`.
#[inline]
pub fn locate(session: usize, stripes: usize) -> (usize, usize) {
    let word = session / LANES;
    (word % stripes, word / stripes * LANES + session % LANES)
}

/// Number of session lanes shard `k` holds when `total` sessions are
/// provisioned across `stripes` shards: all of its words are full except
/// the globally-last word, which carries the batch remainder.
pub fn local_batch(k: usize, stripes: usize, total: usize) -> usize {
    let words = words_for(total);
    if k >= words.min(stripes) {
        return 0;
    }
    let n_words = (words - 1 - k) / stripes + 1;
    let last_lanes = total - (words - 1) * LANES;
    let has_last = (words - 1) % stripes == k;
    (n_words - 1) * LANES + if has_last { last_lanes } else { LANES }
}

/// A batch of controller sessions partitioned into 64-lane word shards,
/// each shard an independent [`SnnNetwork`] stepped on its own pool
/// worker. See the module docs for the mapping and equivalence story.
pub struct ShardedNetwork<S: Scalar> {
    /// Fixed stripe count `T` (worker threads / maximum shard count).
    stripes: usize,
    /// Total provisioned sessions across all shards.
    batch: usize,
    /// Live shards, index `k` holding the words `≡ k (mod stripes)`.
    shards: Vec<SnnNetwork<S>>,
    /// Step workers; `None` until a second shard materializes (so
    /// single-shard deployments never spawn threads) and always `None`
    /// when `stripes == 1` (inline stepping).
    pool: Option<ThreadPool>,
    /// Per-shard staged active mask (local lane indexing).
    shard_active: Vec<Vec<bool>>,
    /// Per-shard "any session staged this tick" summary.
    shard_any: Vec<bool>,
}

impl<S: Scalar> ShardedNetwork<S> {
    /// One-session sharded network. `stripes` fixes the shard mapping
    /// for the lifetime of the instance (it determines where every
    /// future session lives); shards — and the worker pool — materialize
    /// as the batch grows (a ≤64-session deployment never spawns a
    /// thread, whatever `stripes` says).
    pub fn new(cfg: SnnConfig, mode: Mode, stripes: usize) -> Self {
        let stripes = stripes.max(1);
        let first = SnnNetwork::new_batched(cfg, mode, 1);
        ShardedNetwork {
            stripes,
            batch: 1,
            shards: vec![first],
            pool: None,
            shard_active: vec![vec![false; 1]],
            shard_any: vec![false],
        }
    }

    /// Network geometry (shared by every shard).
    pub fn cfg(&self) -> &SnnConfig {
        &self.shards[0].cfg
    }

    /// Total provisioned sessions.
    #[inline]
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// The fixed stripe count the shard mapping was built with.
    #[inline]
    pub fn stripes(&self) -> usize {
        self.stripes
    }

    /// Number of shards currently materialized
    /// (`min(stripes, ceil(batch/64))`).
    #[inline]
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Borrow one shard's network (diagnostics / tests).
    pub fn shard(&self, k: usize) -> &SnnNetwork<S> {
        &self.shards[k]
    }

    /// Mutably borrow one shard's network — the restore path of serving
    /// snapshots writes captured per-shard state back through this.
    /// Callers must preserve the shard invariants (geometry, batch
    /// layout, padding-lane zeros); the snapshot codec does so by
    /// construction because it only restores state captured from an
    /// identically-shaped network.
    pub fn shard_mut(&mut self, k: usize) -> &mut SnnNetwork<S> {
        &mut self.shards[k]
    }

    /// The shared frozen rule θ behind every shard's [`Mode::Plastic`]
    /// (`None` in fixed mode). Chunked multi-engine deployments pass
    /// clones of one `Arc` into every chunk's backend, so *all* shards
    /// of *all* chunks stream the same θ allocation — this accessor is
    /// what the θ-sharing conformance tests `Arc::ptr_eq` against.
    pub fn rule(&self) -> Option<&Arc<NetworkRule>> {
        self.shards[0].mode.rule()
    }

    /// Grow the provisioned session count to `new_batch` **without
    /// resetting live sessions** — each shard's lanes are extended in
    /// place ([`SnnNetwork::grow_batch`] preserves state and zero-fills
    /// the new tail), and newly needed shards start from the zero state.
    pub fn grow_batch(&mut self, new_batch: usize) {
        assert!(new_batch >= self.batch, "batch can only grow");
        if new_batch == self.batch {
            return;
        }
        let n_shards = words_for(new_batch).min(self.stripes);
        for k in 0..n_shards {
            let lb = local_batch(k, self.stripes, new_batch);
            if k < self.shards.len() {
                self.shards[k].grow_batch(lb);
            } else {
                let cfg = self.shards[0].cfg.clone();
                let mode = self.shards[0].mode.clone();
                let mut fresh = SnnNetwork::new_batched(cfg, mode, lb);
                // Late-materialized shards inherit the runtime
                // plasticity gate so a shed server grows consistently.
                fresh.set_plasticity_enabled(self.shards[0].plasticity_enabled());
                if fresh.weights_shared() {
                    // Fixed mode stores one session-invariant weight
                    // copy per shard: a newly materialized shard
                    // inherits it from shard 0.
                    fresh.w1.copy_from_slice(&self.shards[0].w1);
                    fresh.w2.copy_from_slice(&self.shards[0].w2);
                }
                self.shards.push(fresh);
            }
            if k < self.shard_active.len() {
                self.shard_active[k].resize(lb, false);
            } else {
                self.shard_active.push(vec![false; lb]);
                self.shard_any.push(false);
            }
        }
        // The worker pool exists only once there is parallel work to
        // give it (a second shard) — default 16-session servers stay
        // thread-free regardless of `--step-threads`.
        if self.stripes > 1 && self.shards.len() > 1 && self.pool.is_none() {
            self.pool = Some(ThreadPool::new(self.stripes));
        }
        self.batch = new_batch;
    }

    /// Toggle the runtime plasticity gate on every shard (overload
    /// shedding; see [`SnnNetwork::set_plasticity_enabled`]). Shards
    /// materialized by a later [`ShardedNetwork::grow_batch`] inherit
    /// the current setting.
    pub fn set_plasticity_enabled(&mut self, on: bool) {
        for shard in self.shards.iter_mut() {
            shard.set_plasticity_enabled(on);
        }
    }

    /// Whether the runtime plasticity gate is open (uniform across
    /// shards by construction).
    pub fn plasticity_enabled(&self) -> bool {
        self.shards[0].plasticity_enabled()
    }

    /// Install fixed weights (baseline mode) from flat `[W1 ‖ W2]` into
    /// every shard (each shard keeps its own session-invariant copy —
    /// the per-core replication noted in the module docs).
    pub fn load_weights(&mut self, flat: &[f32]) {
        for shard in self.shards.iter_mut() {
            shard.load_weights(flat);
        }
    }

    /// Reset every session of every shard (weights too, in plastic
    /// mode).
    pub fn reset(&mut self) {
        for shard in self.shards.iter_mut() {
            shard.reset();
        }
    }

    /// Reset one session, leaving all others untouched.
    pub fn reset_session(&mut self, session: usize) {
        assert!(session < self.batch, "session out of range");
        let (k, l) = locate(session, self.stripes);
        self.shards[k].reset_session(l);
    }

    /// Start staging a new tick: clear every shard's packed input words
    /// and active flags. Call before [`ShardedNetwork::stage_session`].
    pub fn begin_tick(&mut self) {
        for shard in self.shards.iter_mut() {
            shard.input_mut().clear();
        }
        for act in self.shard_active.iter_mut() {
            for a in act.iter_mut() {
                *a = false;
            }
        }
        for any in self.shard_any.iter_mut() {
            *any = false;
        }
    }

    /// Stage one session's input spikes for the pending tick, scattering
    /// the set bits straight into its shard's packed staging words.
    /// Panics on a duplicate session within one tick (a malformed batch
    /// must fail loudly, not silently double-step).
    pub fn stage_session(&mut self, session: usize, spikes: &[bool]) {
        assert!(
            session < self.batch,
            "session {session} out of range (batch {})",
            self.batch
        );
        assert_eq!(spikes.len(), self.cfg().n_in, "input arity mismatch");
        let (k, l) = locate(session, self.stripes);
        assert!(
            !self.shard_active[k][l],
            "duplicate session {session} in one batch step"
        );
        self.shard_active[k][l] = true;
        self.shard_any[k] = true;
        let staging = self.shards[k].input_mut();
        for (j, &sp) in spikes.iter().enumerate() {
            if sp {
                staging.set(j, l, true);
            }
        }
    }

    /// Advance every staged session one timestep: each shard with any
    /// active session runs its full fused step (event-driven forward,
    /// LIF + trace, plasticity) on its pinned pool worker; idle shards
    /// cost nothing. With one active shard (or `stripes == 1`) the step
    /// runs inline on the caller — no dispatch, no allocation — which
    /// keeps the single-shard path exactly the pre-sharding hot path.
    pub fn step_staged(&mut self) {
        let active_shards = self.shard_any.iter().filter(|&&a| a).count();
        let shards = &mut self.shards;
        let shard_any = &self.shard_any;
        let shard_active = &self.shard_active;
        match &self.pool {
            Some(pool) if active_shards > 1 => {
                pool.scope(|sc| {
                    for (k, shard) in shards.iter_mut().enumerate() {
                        if !shard_any[k] {
                            continue;
                        }
                        let act: &[bool] = &shard_active[k];
                        // Pin shard k to worker k: consecutive ticks of a
                        // shard land on the same core's warm cache, and
                        // the per-shard &mut borrows are disjoint.
                        sc.spawn_on(k, move || {
                            shard.step_staged(act);
                        });
                    }
                });
            }
            _ => {
                for (k, shard) in shards.iter_mut().enumerate() {
                    if shard_any[k] {
                        shard.step_staged(&shard_active[k]);
                    }
                }
            }
        }
    }

    /// Output spike bit of `(neuron, session)` from the most recent step.
    #[inline]
    pub fn output_spike(&self, neuron: usize, session: usize) -> bool {
        let (k, l) = locate(session, self.stripes);
        self.shards[k].output.spikes.get(neuron, l)
    }

    /// Fill `out` with one session's output-population traces as f32
    /// (cleared first; allocation-free once warm).
    pub fn output_traces_session_into(&self, session: usize, out: &mut Vec<f32>) {
        assert!(session < self.batch, "session out of range");
        let (k, l) = locate(session, self.stripes);
        let shard = &self.shards[k];
        let b = shard.batch;
        out.clear();
        for o in 0..shard.cfg.n_out {
            out.push(shard.trace_out.values[o * b + l].to_f32());
        }
    }

    /// One session's output traces as a fresh `Vec` (cold path).
    pub fn output_traces_session(&self, session: usize) -> Vec<f32> {
        let mut out = Vec::new();
        self.output_traces_session_into(session, &mut out);
        out
    }

    /// Presynaptic rows visited by the most recent plastic step, summed
    /// over shards that stepped, per synaptic layer `[L1, L2]`
    /// (event-driven plasticity diagnostics).
    pub fn plasticity_rows_visited(&self) -> [usize; 2] {
        let mut total = [0usize; 2];
        for (k, shard) in self.shards.iter().enumerate() {
            if self.shard_any[k] {
                total[0] += shard.plasticity_rows_visited[0];
                total[1] += shard.plasticity_rows_visited[1];
            }
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::NetworkRule;
    use crate::util::rng::Pcg64;

    #[test]
    fn locate_and_local_batch_tile_the_session_space() {
        for &stripes in &[1usize, 2, 3, 4, 8] {
            for &total in &[1usize, 63, 64, 65, 128, 200, 256, 300] {
                // every session maps into a shard's local range…
                let mut seen = vec![0usize; stripes];
                for s in 0..total {
                    let (k, l) = locate(s, stripes);
                    assert!(k < stripes);
                    assert!(
                        l < local_batch(k, stripes, total),
                        "T={stripes} B={total} s={s} → ({k},{l}) ≥ {}",
                        local_batch(k, stripes, total)
                    );
                    seen[k] += 1;
                }
                // …exactly filling the local batches (a bijection)
                for (k, &count) in seen.iter().enumerate() {
                    let lb = local_batch(k, stripes, total);
                    assert_eq!(count, lb, "T={stripes} B={total} k={k}");
                }
            }
        }
    }

    #[test]
    fn locate_is_stable_under_growth() {
        // The shard/lane of a live session must never change as the
        // batch grows — the migration-free property growth relies on.
        for &stripes in &[2usize, 4] {
            for s in 0..130 {
                let fixed = locate(s, stripes);
                for _total in [s + 1, s + 2, 200, 500] {
                    assert_eq!(locate(s, stripes), fixed);
                }
            }
        }
    }

    #[test]
    fn local_batch_is_monotone_under_growth() {
        for &stripes in &[1usize, 2, 3, 8] {
            for k in 0..stripes {
                let mut prev = 0usize;
                for total in 1..400 {
                    let lb = local_batch(k, stripes, total);
                    assert!(lb >= prev, "shard {k} shrank at B={total} (T={stripes})");
                    prev = lb;
                }
            }
        }
    }

    fn tiny_rule(cfg: &SnnConfig, seed: u64) -> NetworkRule {
        let mut rng = Pcg64::new(seed, 0);
        let mut flat = vec![0.0f32; cfg.n_rule_params()];
        rng.fill_normal_f32(&mut flat, 0.25);
        NetworkRule::from_flat(cfg, &flat)
    }

    #[test]
    fn single_stripe_matches_plain_network() {
        let cfg = SnnConfig::tiny();
        let rule = tiny_rule(&cfg, 50);
        let batch = 5;
        let mut sharded =
            ShardedNetwork::<f32>::new(cfg.clone(), Mode::Plastic(rule.clone().into()), 1);
        sharded.grow_batch(batch);
        let mut plain =
            SnnNetwork::<f32>::new_batched(cfg.clone(), Mode::Plastic(rule.into()), batch);

        let mut rng = Pcg64::new(51, 0);
        let active = vec![true; batch];
        for _ in 0..20 {
            let inmat: Vec<bool> = (0..cfg.n_in * batch).map(|_| rng.bernoulli(0.4)).collect();
            sharded.begin_tick();
            for s in 0..batch {
                let spikes: Vec<bool> = (0..cfg.n_in).map(|j| inmat[j * batch + s]).collect();
                sharded.stage_session(s, &spikes);
            }
            sharded.step_staged();
            plain.step_spikes_masked(&inmat, &active);
            for s in 0..batch {
                for o in 0..cfg.n_out {
                    assert_eq!(sharded.output_spike(o, s), plain.output.spikes.get(o, s));
                }
            }
        }
        for s in 0..batch {
            assert_eq!(
                sharded.output_traces_session(s),
                plain.output_traces_f32_session(s)
            );
        }
    }

    #[test]
    fn multi_stripe_sessions_match_single_sessions() {
        let cfg = SnnConfig::tiny();
        let rule = tiny_rule(&cfg, 52);
        let batch = 67; // two words → two shards at T=4
        let mut sharded =
            ShardedNetwork::<f32>::new(cfg.clone(), Mode::Plastic(rule.clone().into()), 4);
        sharded.grow_batch(batch);
        assert_eq!(sharded.shard_count(), 2);
        // probe sessions in both shards
        let probes = [0usize, 63, 64, 66];
        let mut singles: Vec<SnnNetwork<f32>> = probes
            .iter()
            .map(|_| SnnNetwork::new(cfg.clone(), Mode::Plastic(rule.clone().into())))
            .collect();

        let mut rng = Pcg64::new(53, 0);
        for _ in 0..15 {
            let inmat: Vec<Vec<bool>> = (0..batch)
                .map(|s| (0..cfg.n_in).map(|_| rng.bernoulli(0.3 + 0.005 * s as f64)).collect())
                .collect();
            sharded.begin_tick();
            for (s, row) in inmat.iter().enumerate() {
                sharded.stage_session(s, row);
            }
            sharded.step_staged();
            for (p, &s) in probes.iter().enumerate() {
                singles[p].step_spikes(&inmat[s]);
                for o in 0..cfg.n_out {
                    assert_eq!(
                        sharded.output_spike(o, s),
                        singles[p].output.spikes.get(o, 0),
                        "probe session {s} neuron {o}"
                    );
                }
            }
        }
        for (p, &s) in probes.iter().enumerate() {
            assert_eq!(
                sharded.output_traces_session(s),
                singles[p].output_traces_f32(),
                "probe session {s} traces"
            );
        }
    }

    #[test]
    #[should_panic(expected = "duplicate session")]
    fn duplicate_stage_panics() {
        let cfg = SnnConfig::tiny();
        let mut net = ShardedNetwork::<f32>::new(cfg.clone(), Mode::Fixed, 2);
        let spikes = vec![true; cfg.n_in];
        net.begin_tick();
        net.stage_session(0, &spikes);
        net.stage_session(0, &spikes);
    }
}
