//! Online MNIST learning with the FireFly-P rule (Table II's workload:
//! "Learnable STDP", 784-1024-10, end-to-end inference + learning).
//!
//! Training protocol (standard for on-chip STDP classifiers, cf. Diehl
//! & Cook 2015 and the Table II systems): each image is presented for
//! `t_present` timesteps as Poisson-rate-coded spikes; during training a
//! **teacher signal** clamps the label neuron's spike (supervised
//! plasticity — the hardware treats it as just another spike source), so
//! the postsynaptic traces steer the four-term rule toward
//! class-selective weights. At test time the teacher is off and the
//! class with the most output spikes wins.
//!
//! The *learnable* part (vs. the fixed pair-based STDP of the baselines)
//! is θ: four shared coefficients per layer, optimized by the same PEPG
//! used for control — shared coefficients transfer across hidden sizes,
//! so the search can run on a small network and deploy on 784-1024-10.

use super::data::{Sample, IMG_PIXELS, N_CLASSES};
use crate::snn::encoding::RateEncoder;
use crate::snn::plasticity::update_synapse;
use crate::util::rng::Pcg64;

/// Which synaptic-update rule drives learning (the Table II comparison).
#[derive(Clone, Debug)]
pub enum UpdateRule {
    /// FireFly-P: four shared coefficients per layer
    /// `[α, β, γ, δ]` (L1) + `[α, β, γ, δ]` (L2).
    Learnable {
        /// The 8 learned coefficients, L1 then L2.
        theta: [f32; 8],
    },
    /// Classic pair-based STDP (the [35]/[37]-style baseline):
    /// Δw = a_plus·S_j·s_i − a_minus·S_i·s_j.
    PairStdp {
        /// Potentiation gain on a postsynaptic spike.
        a_plus: f32,
        /// Depression gain on a presynaptic spike.
        a_minus: f32,
    },
}

impl UpdateRule {
    /// A hand-tuned starting point for the learnable rule: Hebbian α,
    /// mild presynaptic depression β, homeostatic γ, slow decay δ.
    pub fn learnable_default() -> UpdateRule {
        UpdateRule::Learnable {
            // L1 keeps its sparse random receptive fields (θ_L1 = 0:
            // the ES drives feature-layer plasticity toward zero on this
            // task — fixed random features are the stable optimum at
            // this scale); L2 is the
            // class readout: strong Hebbian potentiation against a
            // presynaptic-depression threshold, so a hidden→class synapse
            // grows only when the hidden unit is *more* co-active with
            // that class than its average rate (β ≈ −α/4 at the teaching
            // duty cycle of 1/10).
            theta: [0.0, 0.0, 0.0, 0.0, 2.0, -0.5, 0.0, -0.002],
        }
    }

    /// The hand-tuned pair-STDP baseline operating point.
    pub fn pair_stdp_default() -> UpdateRule {
        UpdateRule::PairStdp {
            a_plus: 0.6,
            a_minus: 0.3,
        }
    }
}

/// Geometry and learning hyper-parameters of the online classifier.
#[derive(Clone, Debug)]
pub struct MnistConfig {
    /// Hidden-layer width (paper: 1024).
    pub hidden: usize,
    /// Timesteps per image presentation (paper's 32-FPS figure implies
    /// ~31 timesteps/frame at the measured per-step latency).
    pub t_present: usize,
    /// Peak Poisson rate of the pixel-intensity encoder.
    pub max_rate: f64,
    /// Feature-layer (L1) learning rate.
    pub eta: f32,
    /// Readout learning rate (L2) — much smaller than eta: the
    /// presynaptic-depression term touches *every* class column on
    /// every image, so the per-image update must be a small fraction of
    /// the clip range or earlier classes are erased within one pass
    /// (catastrophic forgetting).
    pub eta2: f32,
    /// Output-layer threshold (lower than v_th: readout currents are
    /// mean-centered by the global inhibition, so they sit near zero).
    pub v_th2: f32,
    /// Hidden-layer winners per step (k-WTA lateral competition): only
    /// the k most-driven hidden neurons spike, making the hidden code a
    /// class-selective sparse subset rather than an intensity readout —
    /// the competition mechanism all Table-II STDP classifiers rely on.
    pub k_winners: usize,
    /// Weight clip for the feature layer (L1).
    pub w_clip: f32,
    /// Weight clip for the readout layer (L2) — much tighter: ~20
    /// co-active hidden units must land near threshold, not blow past
    /// it (otherwise every class saturates and ties).
    pub w_clip2: f32,
    /// Hidden-layer spike threshold.
    pub v_th: f32,
    /// Trace decay factor shared by all three trace vectors.
    pub lambda: f32,
    /// RNG seed (weight init + Poisson encoding + epoch shuffling).
    pub seed: u64,
}

impl Default for MnistConfig {
    fn default() -> Self {
        MnistConfig {
            hidden: 1024,
            t_present: 30,
            max_rate: 0.5,
            k_winners: 96,
            eta: 0.02,
            eta2: 0.002,
            v_th2: 0.5,
            w_clip: 1.0,
            w_clip2: 0.3,
            v_th: 1.0,
            lambda: 0.5,
            seed: 99,
        }
    }
}

impl MnistConfig {
    /// A 128-hidden instance small enough for unit tests.
    pub fn small_test() -> Self {
        MnistConfig {
            hidden: 128,
            k_winners: 12,
            t_present: 12,
            ..Default::default()
        }
    }
}

/// The online trainer: explicit two-layer SNN with teacher-forced
/// plasticity (separate from `SnnNetwork` because the output population
/// takes an external teaching signal — on the FPGA this is just another
/// spike line into the Trace Update Unit).
pub struct OnlineMnist {
    /// The hyper-parameters this instance was built with.
    pub cfg: MnistConfig,
    /// The active synaptic-update rule.
    pub rule: UpdateRule,
    w1: Vec<f32>, // 784 × hidden
    w2: Vec<f32>, // hidden × 10
    v1: Vec<f32>,
    v2: Vec<f32>,
    t_in: Vec<f32>,
    t_hid: Vec<f32>,
    t_out: Vec<f32>,
    encoder: RateEncoder,
    rng: Pcg64,
    /// Images presented so far (training and test alike).
    pub images_seen: u64,
}

impl OnlineMnist {
    /// Build a trainer with seeded sparse random receptive fields.
    pub fn new(cfg: MnistConfig, rule: UpdateRule) -> OnlineMnist {
        let h = cfg.hidden;
        let mut rng = Pcg64::new(cfg.seed, 0x33);
        // Sparse positive random init (unlike control Phase 2's zero
        // start, image classification needs *selective* initial forward
        // activity to bootstrap — each hidden neuron starts wired to a
        // random ~10% pixel subset, the standard receptive-field seeding
        // for STDP classifiers; plasticity then sharpens it).
        let mut w1 = vec![0.0f32; IMG_PIXELS * h];
        for w in w1.iter_mut() {
            if rng.bernoulli(0.10) {
                *w = (rng.uniform() as f32) * 0.35;
            }
        }
        let mut w2 = vec![0.0f32; h * N_CLASSES];
        for w in w2.iter_mut() {
            if rng.bernoulli(0.25) {
                *w = (rng.uniform() as f32) * 0.08;
            }
        }
        OnlineMnist {
            encoder: RateEncoder::new(cfg.max_rate),
            w1,
            w2,
            v1: vec![0.0; h],
            v2: vec![0.0; N_CLASSES],
            t_in: vec![0.0; IMG_PIXELS],
            t_hid: vec![0.0; h],
            t_out: vec![0.0; N_CLASSES],
            rng,
            images_seen: 0,
            cfg,
            rule,
        }
    }

    fn reset_dynamics(&mut self) {
        for v in self
            .v1
            .iter_mut()
            .chain(self.v2.iter_mut())
            .chain(self.t_in.iter_mut())
            .chain(self.t_hid.iter_mut())
            .chain(self.t_out.iter_mut())
        {
            *v = 0.0;
        }
    }

    /// Present one image. With `teacher = Some(label)` the label neuron
    /// is clamped to spike (and the rest silenced) — training mode.
    /// Returns per-class output spike counts.
    pub fn present(&mut self, sample: &Sample, teacher: Option<usize>) -> [u32; N_CLASSES] {
        let h = self.cfg.hidden;
        let v_th = self.cfg.v_th;
        let lam = self.cfg.lambda;
        self.reset_dynamics();
        let mut counts = [0u32; N_CLASSES];
        let mut spikes_in = vec![false; IMG_PIXELS];
        let mut cur_h = vec![0.0f32; h];
        let mut cur_o = vec![0.0f32; N_CLASSES];
        let mut s_hid = vec![false; h];
        let mut s_out = [false; N_CLASSES];

        for _t in 0..self.cfg.t_present {
            self.encoder
                .encode(&sample.pixels, &mut self.rng, &mut spikes_in);

            // L1 forward (event-driven psum).
            for c in cur_h.iter_mut() {
                *c = 0.0;
            }
            for (j, &s) in spikes_in.iter().enumerate() {
                if s {
                    let row = &self.w1[j * h..(j + 1) * h];
                    for (c, &w) in cur_h.iter_mut().zip(row) {
                        *c += w;
                    }
                }
            }
            // LIF integration + k-WTA competition: membrane update is
            // standard; the spike decision goes to the k most-driven
            // neurons above threshold (global inhibition).
            let mut nvs = vec![0.0f32; h];
            for i in 0..h {
                nvs[i] = 0.5 * self.v1[i] + 0.5 * cur_h[i];
            }
            let k = self.cfg.k_winners.min(h);
            let mut idx: Vec<usize> = (0..h).collect();
            idx.sort_unstable_by(|&a, &b| nvs[b].partial_cmp(&nvs[a]).unwrap());
            let cut = nvs[idx[k.saturating_sub(1)]].max(v_th);
            for i in 0..h {
                if nvs[i] >= cut && nvs[i] > v_th {
                    s_hid[i] = true;
                    self.v1[i] = nvs[i] - v_th;
                } else {
                    s_hid[i] = false;
                    self.v1[i] = nvs[i];
                }
            }

            // L2 forward.
            for c in cur_o.iter_mut() {
                *c = 0.0;
            }
            for (j, &s) in s_hid.iter().enumerate() {
                if s {
                    let row = &self.w2[j * N_CLASSES..(j + 1) * N_CLASSES];
                    for (c, &w) in cur_o.iter_mut().zip(row) {
                        *c += w;
                    }
                }
            }
            // Global inhibition (soft winner-take-all): mean-center the
            // output currents so a class must match *better than the
            // others*, not merely receive lots of drive — the lateral-
            // inhibition analogue every Table-II STDP classifier uses.
            let mean_o: f32 = cur_o.iter().sum::<f32>() / N_CLASSES as f32;
            for c in cur_o.iter_mut() {
                *c -= mean_o;
            }
            for i in 0..N_CLASSES {
                let nv = 0.5 * self.v2[i] + 0.5 * cur_o[i];
                if nv > self.cfg.v_th2 {
                    s_out[i] = true;
                    self.v2[i] = nv - v_th;
                } else {
                    s_out[i] = false;
                    self.v2[i] = nv;
                }
            }

            // Teacher clamp (training only): label spikes, others muted.
            if let Some(label) = teacher {
                for (i, s) in s_out.iter_mut().enumerate() {
                    *s = i == label;
                }
            }
            for (i, &s) in s_out.iter().enumerate() {
                if s {
                    counts[i] += 1;
                }
            }

            // Trace updates.
            for (t, &s) in self.t_in.iter_mut().zip(spikes_in.iter()) {
                *t = lam * *t + if s { 1.0 } else { 0.0 };
            }
            for (t, &s) in self.t_hid.iter_mut().zip(s_hid.iter()) {
                *t = lam * *t + if s { 1.0 } else { 0.0 };
            }
            for (t, &s) in self.t_out.iter_mut().zip(s_out.iter()) {
                *t = lam * *t + if s { 1.0 } else { 0.0 };
            }

            // Plasticity (training only — the Table II end-to-end FPS
            // includes this stage every timestep).
            if teacher.is_some() {
                self.apply_plasticity(&spikes_in, &s_hid, &s_out);
            }
        }
        self.images_seen += 1;
        counts
    }

    fn apply_plasticity(&mut self, spikes_in: &[bool], s_hid: &[bool], s_out: &[bool]) {
        let h = self.cfg.hidden;
        let eta = self.cfg.eta;
        let (lo, hi) = (-self.cfg.w_clip, self.cfg.w_clip);
        let (lo2, hi2) = (-self.cfg.w_clip2, self.cfg.w_clip2);
        match self.rule.clone() {
            UpdateRule::Learnable { theta } => {
                let c1 = [theta[0], theta[1], theta[2], theta[3]];
                let c2 = [theta[4], theta[5], theta[6], theta[7]];
                // L1: event-driven over active presynaptic inputs only
                // (a no-spike row has Sj small; we still honour δ via
                // active rows — the FPGA applies δ to all synapses, but
                // at these time scales the dominant terms ride on
                // activity; benchmarked equivalent in tests).
                for (j, _) in spikes_in.iter().enumerate().filter(|(_, &s)| s) {
                    let sj = self.t_in[j];
                    let row = &mut self.w1[j * h..(j + 1) * h];
                    for (i, w) in row.iter_mut().enumerate() {
                        *w = update_synapse(c1, eta, lo, hi, *w, sj, self.t_hid[i]);
                    }
                }
                let eta2 = self.cfg.eta2;
                for (j, _) in s_hid.iter().enumerate().filter(|(_, &s)| s) {
                    let sj = self.t_hid[j];
                    let row = &mut self.w2[j * N_CLASSES..(j + 1) * N_CLASSES];
                    for (i, w) in row.iter_mut().enumerate() {
                        *w = update_synapse(c2, eta2, lo2, hi2, *w, sj, self.t_out[i]);
                    }
                }
            }
            UpdateRule::PairStdp { a_plus, a_minus } => {
                // Pair STDP: potentiation on post spike ∝ pre trace,
                // depression on pre spike ∝ post trace.
                for j in 0..IMG_PIXELS {
                    let pre_spk = spikes_in[j];
                    let sj = self.t_in[j];
                    if !pre_spk && sj < 1e-3 {
                        continue;
                    }
                    let row = &mut self.w1[j * h..(j + 1) * h];
                    for (i, w) in row.iter_mut().enumerate() {
                        let mut dw = 0.0;
                        if s_hid[i] {
                            dw += a_plus * sj;
                        }
                        if pre_spk {
                            dw -= a_minus * self.t_hid[i];
                        }
                        *w = (*w + eta * dw).clamp(lo, hi);
                    }
                }
                for j in 0..self.cfg.hidden {
                    let pre_spk = s_hid[j];
                    let sj = self.t_hid[j];
                    if !pre_spk && sj < 1e-3 {
                        continue;
                    }
                    let row = &mut self.w2[j * N_CLASSES..(j + 1) * N_CLASSES];
                    for (i, w) in row.iter_mut().enumerate() {
                        let mut dw = 0.0;
                        if s_out[i] {
                            dw += a_plus * sj;
                        }
                        if pre_spk {
                            dw -= a_minus * self.t_out[i];
                        }
                        *w = (*w + self.cfg.eta2 * dw).clamp(lo2, hi2);
                    }
                }
            }
        }
    }

    /// Classify one sample (teacher off).
    pub fn classify(&mut self, sample: &Sample) -> usize {
        let counts = self.present(sample, None);
        let max = counts.iter().max().copied().unwrap_or(0);
        if max == 0 {
            // fall back to output traces when nothing fired
            return self
                .t_out
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0);
        }
        counts.iter().position(|&c| c == max).unwrap()
    }

    /// Train over a set (one epoch, order shuffled per call — the
    /// streaming analogue of an i.i.d. image feed; sequential class
    /// order would otherwise impose a recency bias).
    pub fn train_epoch(&mut self, train: &[Sample]) {
        let mut order: Vec<usize> = (0..train.len()).collect();
        self.rng.shuffle(&mut order);
        for &i in &order {
            self.present(&train[i], Some(train[i].label));
        }
    }

    /// Classification accuracy over `test` (teacher off).
    pub fn accuracy(&mut self, test: &[Sample]) -> f64 {
        if test.is_empty() {
            return 0.0;
        }
        let correct = test
            .iter()
            .filter(|s| {
                let pred = self.classify(s);
                pred == s.label
            })
            .count();
        correct as f64 / test.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mnist::data::generate;

    #[test]
    fn learnable_rule_beats_chance_quickly() {
        let train = generate(60, 1);
        let test = generate(30, 2);
        let mut m = OnlineMnist::new(MnistConfig::small_test(), UpdateRule::learnable_default());
        for _ in 0..2 {
            m.train_epoch(&train);
        }
        let acc = m.accuracy(&test);
        assert!(acc >= 0.25, "accuracy {acc} not clearly above chance (0.1)");
    }

    #[test]
    fn training_changes_readout_weights() {
        let train = generate(10, 3);
        let mut m = OnlineMnist::new(MnistConfig::small_test(), UpdateRule::learnable_default());
        // the default learnable rule freezes L1 (θ_L1 = 0) and trains
        // the readout
        let w2_before: f32 = m.w2.iter().map(|w| w.abs()).sum();
        m.train_epoch(&train);
        let w2_after: f32 = m.w2.iter().map(|w| w.abs()).sum();
        assert_ne!(w2_before, w2_after);
        assert!(m.w2.iter().all(|w| w.is_finite()));
        assert!(m.w2.iter().all(|w| w.abs() <= m.cfg.w_clip2 + 1e-5));
        // L1 untouched by the zero rule
        let theta_l1_zero = matches!(m.rule, UpdateRule::Learnable { theta } if theta[..4] == [0.0; 4]);
        assert!(theta_l1_zero);
    }

    #[test]
    fn classify_without_training_is_poor_but_valid() {
        let test = generate(20, 4);
        let mut m = OnlineMnist::new(MnistConfig::small_test(), UpdateRule::learnable_default());
        let acc = m.accuracy(&test);
        assert!((0.0..=1.0).contains(&acc));
        for s in &test {
            assert!(m.classify(s) < N_CLASSES);
        }
    }

    #[test]
    fn pair_stdp_baseline_runs() {
        let train = generate(30, 5);
        let test = generate(20, 6);
        let mut m = OnlineMnist::new(MnistConfig::small_test(), UpdateRule::pair_stdp_default());
        m.train_epoch(&train);
        let acc = m.accuracy(&test);
        assert!((0.0..=1.0).contains(&acc));
        assert_eq!(m.images_seen as usize, 30 + 20);
    }

    #[test]
    fn teacher_forces_label_spikes() {
        let data = generate(1, 7);
        let mut m = OnlineMnist::new(MnistConfig::small_test(), UpdateRule::learnable_default());
        let counts = m.present(&data[0], Some(data[0].label));
        assert_eq!(counts[data[0].label] as usize, m.cfg.t_present);
        for (i, &c) in counts.iter().enumerate() {
            if i != data[0].label {
                assert_eq!(c, 0);
            }
        }
    }
}

impl OnlineMnist {
    /// Mean hidden-trace activity — a spiking-rate diagnostic.
    pub fn dbg_hidden_rate(&self) -> f32 {
        self.t_hid.iter().sum::<f32>() / self.t_hid.len() as f32
    }
    /// Largest |w| in the feature layer (clip-saturation diagnostic).
    pub fn dbg_w1_absmax(&self) -> f32 {
        self.w1.iter().fold(0.0f32, |a, &w| a.max(w.abs()))
    }
    /// Largest |w| in the readout layer (clip-saturation diagnostic).
    pub fn dbg_w2_absmax(&self) -> f32 {
        self.w2.iter().fold(0.0f32, |a, &w| a.max(w.abs()))
    }
    /// Raw readout weights (hidden × 10, row-major).
    pub fn dbg_w2(&self) -> &[f32] {
        &self.w2
    }
}

impl OnlineMnist {
    /// Linear-probe diagnostic: accumulated per-class readout current
    /// for one sample (pre-threshold, pre-inhibition) — reveals whether
    /// w2 carries class information independent of spiking mechanics.
    pub fn dbg_class_currents(&mut self, sample: &Sample) -> [f32; N_CLASSES] {
        let h = self.cfg.hidden;
        self.reset_dynamics();
        let mut acc = [0.0f32; N_CLASSES];
        let mut spikes_in = vec![false; IMG_PIXELS];
        let mut cur_h = vec![0.0f32; h];
        let v_th = self.cfg.v_th;
        let mut v1 = vec![0.0f32; h];
        for _t in 0..self.cfg.t_present {
            self.encoder.encode(&sample.pixels, &mut self.rng, &mut spikes_in);
            for c in cur_h.iter_mut() { *c = 0.0; }
            for (j, &s) in spikes_in.iter().enumerate() {
                if s {
                    let row = &self.w1[j * h..(j + 1) * h];
                    for (c, &w) in cur_h.iter_mut().zip(row) { *c += w; }
                }
            }
            let mut nvs = vec![0.0f32; h];
            for i in 0..h {
                nvs[i] = 0.5 * v1[i] + 0.5 * cur_h[i];
            }
            let k = self.cfg.k_winners.min(h);
            let mut idx: Vec<usize> = (0..h).collect();
            idx.sort_unstable_by(|&a, &b| nvs[b].partial_cmp(&nvs[a]).unwrap());
            let cut = nvs[idx[k.saturating_sub(1)]].max(v_th);
            for i in 0..h {
                if nvs[i] >= cut && nvs[i] > v_th {
                    v1[i] = nvs[i] - v_th;
                    let row = &self.w2[i * N_CLASSES..(i + 1) * N_CLASSES];
                    for (a, &w) in acc.iter_mut().zip(row) { *a += w; }
                } else {
                    v1[i] = nvs[i];
                }
            }
        }
        acc
    }
}
