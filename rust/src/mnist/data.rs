//! Synthetic 28×28 digit corpus — the offline-image substitute for MNIST
//! (no network access in this environment; see DESIGN.md §2).
//!
//! Ten structural glyph templates (7×7 stroke grids) are rendered to
//! 28×28 with per-sample random affine jitter (translation, scale,
//! shear), stroke-width variation and pixel noise, giving a
//! 784-dimensional, 10-class, intensity-coded classification problem
//! with real intra-class variability. Deterministic per (seed, index).

use crate::util::rng::Pcg64;

/// Rendered image side length (MNIST-shaped: 28×28).
pub const IMG_SIDE: usize = 28;
/// Pixels per image — the SNN input dimensionality (784).
pub const IMG_PIXELS: usize = IMG_SIDE * IMG_SIDE;
/// Digit classes (0–9).
pub const N_CLASSES: usize = 10;

/// 7×7 glyph templates ('#' = stroke).
const TEMPLATES: [&str; 10] = [
    // 0
    ".#####.\n#.....#\n#.....#\n#.....#\n#.....#\n#.....#\n.#####.",
    // 1
    "...#...\n..##...\n.#.#...\n...#...\n...#...\n...#...\n.#####.",
    // 2
    ".#####.\n#.....#\n......#\n..###..\n.#.....\n#......\n#######",
    // 3
    "######.\n......#\n......#\n..####.\n......#\n......#\n######.",
    // 4
    "#....#.\n#....#.\n#....#.\n#######\n.....#.\n.....#.\n.....#.",
    // 5
    "#######\n#......\n#......\n######.\n......#\n......#\n######.",
    // 6
    ".#####.\n#......\n#......\n######.\n#.....#\n#.....#\n.#####.",
    // 7
    "#######\n......#\n.....#.\n....#..\n...#...\n..#....\n..#....",
    // 8
    ".#####.\n#.....#\n#.....#\n.#####.\n#.....#\n#.....#\n.#####.",
    // 9
    ".#####.\n#.....#\n#.....#\n.######\n......#\n......#\n.#####.",
];

/// One labeled image.
#[derive(Clone, Debug)]
pub struct Sample {
    /// [`IMG_PIXELS`] intensities in `[0, 1]`, row-major.
    pub pixels: Vec<f32>,
    /// Digit class in `0..N_CLASSES`.
    pub label: usize,
}

/// Parse a template into stroke points in [0, 1]² (cell centers).
fn template_points(digit: usize) -> Vec<(f32, f32)> {
    let mut pts = Vec::new();
    for (r, line) in TEMPLATES[digit].lines().enumerate() {
        for (c, ch) in line.chars().enumerate() {
            if ch == '#' {
                pts.push(((c as f32 + 0.5) / 7.0, (r as f32 + 0.5) / 7.0));
            }
        }
    }
    pts
}

/// Render one digit with random augmentation.
pub fn render_digit(digit: usize, rng: &mut Pcg64) -> Vec<f32> {
    assert!(digit < N_CLASSES);
    let pts = template_points(digit);
    let mut img = vec![0.0f32; IMG_PIXELS];

    // Random affine: scale, shear, translate (kept small so the class
    // stays recognizable).
    let scale = 0.80 + 0.20 * rng.uniform() as f32;
    let shear = (rng.uniform() as f32 - 0.5) * 0.25;
    let dx = (rng.uniform() as f32 - 0.5) * 0.15;
    let dy = (rng.uniform() as f32 - 0.5) * 0.15;
    let stroke = 1.1 + 0.8 * rng.uniform() as f32; // px radius at 28×28

    for &(tx, ty) in &pts {
        // center, scale, shear, translate
        let cx = (tx - 0.5) * scale + shear * (ty - 0.5) + 0.5 + dx;
        let cy = (ty - 0.5) * scale + 0.5 + dy;
        let px = cx * IMG_SIDE as f32;
        let py = cy * IMG_SIDE as f32;
        // stamp a soft disc
        let r_cells = stroke.ceil() as i32 + 1;
        let (ix, iy) = (px as i32, py as i32);
        for oy in -r_cells..=r_cells {
            for ox in -r_cells..=r_cells {
                let (x, y) = (ix + ox, iy + oy);
                if x < 0 || y < 0 || x >= IMG_SIDE as i32 || y >= IMG_SIDE as i32 {
                    continue;
                }
                let d2 = (x as f32 + 0.5 - px).powi(2) + (y as f32 + 0.5 - py).powi(2);
                let v = (-d2 / (stroke * stroke)).exp();
                let idx = y as usize * IMG_SIDE + x as usize;
                img[idx] = (img[idx] + v).min(1.0);
            }
        }
    }

    // Pixel noise + faint background speckle.
    for p in img.iter_mut() {
        let noise = (rng.uniform() as f32 - 0.5) * 0.08;
        *p = (*p + noise).clamp(0.0, 1.0);
    }
    img
}

/// A reproducible dataset of `n` samples with balanced classes.
pub fn generate(n: usize, seed: u64) -> Vec<Sample> {
    let mut rng = Pcg64::new(seed, 0xD1617);
    (0..n)
        .map(|i| {
            let label = i % N_CLASSES;
            Sample {
                pixels: render_digit(label, &mut rng),
                label,
            }
        })
        .collect()
}

/// Mean per-class pixel correlation — a sanity measure that classes are
/// distinguishable (used by tests; a degenerate generator would score
/// near the off-class level).
pub fn class_separability(samples: &[Sample]) -> (f64, f64) {
    let mut same = Vec::new();
    let mut diff = Vec::new();
    for (i, a) in samples.iter().enumerate() {
        for b in samples.iter().skip(i + 1) {
            let corr = correlation(&a.pixels, &b.pixels);
            if a.label == b.label {
                same.push(corr);
            } else {
                diff.push(corr);
            }
        }
    }
    (
        crate::util::stats::mean(&same),
        crate::util::stats::mean(&diff),
    )
}

fn correlation(a: &[f32], b: &[f32]) -> f64 {
    let n = a.len() as f64;
    let ma = a.iter().map(|&x| x as f64).sum::<f64>() / n;
    let mb = b.iter().map(|&x| x as f64).sum::<f64>() / n;
    let mut num = 0.0;
    let mut da = 0.0;
    let mut db = 0.0;
    for (&x, &y) in a.iter().zip(b) {
        let (x, y) = (x as f64 - ma, y as f64 - mb);
        num += x * y;
        da += x * x;
        db += y * y;
    }
    num / (da.sqrt() * db.sqrt()).max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn templates_are_all_7x7() {
        for (d, t) in TEMPLATES.iter().enumerate() {
            let lines: Vec<&str> = t.lines().collect();
            assert_eq!(lines.len(), 7, "digit {d} rows");
            for l in lines {
                assert_eq!(l.len(), 7, "digit {d} cols");
            }
            assert!(!template_points(d).is_empty());
        }
    }

    #[test]
    fn images_are_valid() {
        let data = generate(40, 1);
        assert_eq!(data.len(), 40);
        for s in &data {
            assert_eq!(s.pixels.len(), IMG_PIXELS);
            assert!(s.label < N_CLASSES);
            assert!(s.pixels.iter().all(|&p| (0.0..=1.0).contains(&p)));
            // a digit must have meaningful ink
            let ink: f32 = s.pixels.iter().sum();
            assert!(ink > 10.0, "label {} ink {ink}", s.label);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(10, 7);
        let b = generate(10, 7);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.pixels, y.pixels);
        }
        let c = generate(10, 8);
        assert_ne!(a[0].pixels, c[0].pixels);
    }

    #[test]
    fn classes_are_separable() {
        let data = generate(60, 2);
        let (same, diff) = class_separability(&data);
        assert!(
            same > diff + 0.15,
            "within-class corr {same:.3} must exceed between-class {diff:.3}"
        );
    }

    #[test]
    fn augmentation_varies_within_class() {
        let data = generate(40, 3);
        let zeros: Vec<&Sample> = data.iter().filter(|s| s.label == 0).collect();
        assert!(zeros.len() >= 2);
        assert_ne!(zeros[0].pixels, zeros[1].pixels);
    }
}
