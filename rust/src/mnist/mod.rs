//! MNIST online-learning workload (Table II) over the synthetic digit
//! corpus (MNIST itself is download-gated in this environment — see
//! DESIGN.md §2 for why the substitution preserves the comparison).

pub mod data;
pub mod train;

pub use data::{generate, Sample, IMG_PIXELS, N_CLASSES};
pub use train::{MnistConfig, OnlineMnist, UpdateRule};
