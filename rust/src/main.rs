//! firefly-p — command-line entrypoint for the FireFly-P reproduction.
//!
//! Subcommands cover the full paper workflow:
//!   train-rule    Phase 1: offline PEPG over plasticity coefficients
//!   adapt         Phase 2: online adaptation episode (any backend)
//!   serve         TCP control server around a deployed controller
//!   mnist         Table II workload: online MNIST learning
//!   fpga-report   Table I resources + power + Fig. 4 floorplan
//!   artifacts     list AOT artifacts the runtime can load

use firefly_p::backend::{
    BackendKind, FpgaBackend, NativeBackend, ReplicatedBackend, SnnBackend, TypedNativeBackend,
    XlaBackend,
};
use firefly_p::coordinator::jobs::Precision;
use firefly_p::util::fixed::Qfx;
use firefly_p::util::fp16::F16;
use std::sync::Arc;

use firefly_p::coordinator::adapt_loop::{run_adaptation, AdaptConfig};
use firefly_p::coordinator::batch_adapt::{
    parse_schedule, run_batch_adaptation, run_chunked_adaptation, scenarios_for_grid,
    BatchAdaptConfig, ChunkBackendSpec, GridSummary,
};
use firefly_p::coordinator::offline::{genome_io, train_rule, TrainConfig};
use firefly_p::coordinator::server::{ControlServer, ServerConfig};
use firefly_p::coordinator::{JobManager, JobManagerConfig, JobModel, Metrics};
use firefly_p::env::{eval_grid, family_of, make_env, train_grid, Perturbation};
use firefly_p::es::eval::GenomeKind;
use firefly_p::fpga::power::{Activity, PowerModel};
use firefly_p::fpga::resources::{NetGeometry, ResourceReport};
use firefly_p::fpga::{layout, HwConfig};
use firefly_p::mnist;
use firefly_p::runtime::Registry;
use firefly_p::snn::NetworkRule;
use firefly_p::util::argparse::{flag, opt, Args, Parser};

fn parser() -> Parser {
    Parser::new(
        "firefly-p",
        "FPGA-accelerated SNN plasticity for robust adaptive control (full-system reproduction)",
    )
    .global_opt("seed", "rng seed", Some("42"))
    .command(
        "train-rule",
        "Phase 1: offline optimization of the plasticity rule (or weight baseline)",
        vec![
            opt("env", "environment: ant-dir | cheetah-vel | reacher", "ant-dir"),
            opt("generations", "PEPG generations", "50"),
            opt("pairs", "symmetric sample pairs per generation", "16"),
            opt("hidden", "hidden layer width", "128"),
            opt("out", "output genome file", "results/rule.bin"),
            flag("weights", "train the weight baseline instead of a rule"),
            flag("quiet", "suppress per-generation logs"),
        ],
    )
    .command(
        "adapt",
        "Phase 2: online adaptation over the scenario grid (batched engine)",
        vec![
            opt("env", "environment", "ant-dir"),
            opt("genome", "genome file from train-rule", "results/rule.bin"),
            opt("backend", "native | xla | fpga", "native"),
            opt("perturb", "e.g. leg:0,1 | gain:0.3 | wind:1,-0.5", ""),
            opt("perturb-at", "timestep to inject the perturbation", "100"),
            opt("task", "task index in the training grid", "0"),
            opt(
                "batch",
                "concurrent sessions per engine run (native backend batches them)",
                "1",
            ),
            opt(
                "grid",
                "scenario fan-out: task (one --task) | train (8 tasks) | eval (72 novel tasks)",
                "task",
            ),
            opt(
                "perturb-schedule",
                "per-session ';'-separated spec@t entries assigned round-robin, \
                 e.g. leg:0@80;none;gain:0.5@100 (overrides --perturb)",
                "",
            ),
            opt(
                "prec",
                "backend arithmetic: f32 | f16 (bit-accurate binary16) | qfx \
                 (hardware-parity Q5.10 integer fixed point, pinned bit-exact \
                 against the FPGA simulator). Native backend only — xla/fpga \
                 fix their own datapath",
                "f32",
            ),
            opt(
                "adapt-threads",
                "scenario chunks stepped in parallel on pinned workers, each chunk \
                 owning its own backend + envs (plant AND network; 0 = all CPU \
                 cores; capped at --batch, the sessions per engine run). Native \
                 backend only — xla/fpga batches fall back to the single-threaded \
                 ReplicatedBackend engine. Orthogonal to serve's --step-threads, \
                 which shards the network half of one backend's step; chunk \
                 backends here step their networks inline",
                "1",
            ),
        ],
    )
    .command(
        "serve",
        "serve deployed controllers over TCP (multi-session, batched)",
        vec![
            opt("env", "environment (sets I/O geometry)", "cheetah-vel"),
            opt("genome", "genome file", "results/rule.bin"),
            opt("backend", "native | xla | fpga", "xla"),
            opt("addr", "bind address", "127.0.0.1:7690"),
            opt(
                "sessions",
                "max concurrent client sessions (native batches them; xla/fpga replicate)",
                "16",
            ),
            opt(
                "prec",
                "serving arithmetic: f32 | f16 | qfx (hardware-parity Q5.10 \
                 fixed point). Native backend only; JOB SUBMIT picks its own \
                 prec per submission",
                "f32",
            ),
            opt(
                "step-threads",
                "worker threads the native backend shards batched steps across \
                 (64-lane word shards; 0 = all CPU cores)",
                "0",
            ),
            opt(
                "job-threads",
                "dedicated job-runner threads executing JOB SUBMIT grid sweeps \
                 (adaptation-as-a-service) off the serving path; 0 disables the \
                 job subsystem. Composes with adapt's --adapt-threads: each \
                 runner steps its job's scenario chunks via the chunked engine",
                "1",
            ),
            opt(
                "job-queue",
                "bound on queued (not yet running) jobs; submits beyond it get \
                 a typed `ERR job-queue-full` rejection instead of stalling \
                 live control ticks",
                "8",
            ),
            opt(
                "job-dir",
                "durable job checkpoint directory: every job persists its \
                 batch-aligned progress here (atomic writes) and interrupted \
                 sweeps resume bit-identically after a restart; empty = \
                 in-memory only",
                "",
            ),
            opt(
                "line-cap",
                "max request-line bytes; longer lines get `ERR line-too-long` \
                 and the connection survives",
                "65536",
            ),
            opt(
                "read-timeout-ms",
                "disconnect a client idle for this many milliseconds \
                 (0 = never; the session slot is reclaimed either way)",
                "0",
            ),
            flag(
                "fair-share",
                "weighted start-time fair queuing across (family, client) \
                 lanes for job runners instead of strict FIFO; per-spec \
                 weight=<n> scales a lane's share",
            ),
            opt(
                "admission-wait-ms",
                "deadline-aware job admission: refuse JOB SUBMIT with a typed \
                 `ERR overloaded retry-ms=<n>` when the queue's projected \
                 wait exceeds this bound (0 = admit until --job-queue fills)",
                "0",
            ),
            opt(
                "tick-deadline-us",
                "serving-tick deadline in microseconds: sustained overruns \
                 shed plasticity (fixed-weights serving) until the stepper \
                 catches up, then restore automatically; 0 disables the \
                 watchdog",
                "0",
            ),
            opt(
                "state-dir",
                "durable serving-state directory: full session snapshots \
                 (weights, membranes, traces, resume tokens) land here \
                 atomically at tick boundaries, and on restart the newest \
                 valid one warm-starts the server — clients re-attach with \
                 RESUME <token> bit-exactly; empty = in-memory only",
                "",
            ),
            opt(
                "snapshot-every-ticks",
                "serving ticks between durable snapshots (with --state-dir)",
                "16",
            ),
            opt(
                "stream-lag-cap",
                "byte cap on one JOB SUBSCRIBE/RESULTS follower's unsent \
                 backlog; at the cap the follower is cut with a typed \
                 `ERR lagged next=<row>` and can re-subscribe from there",
                "1048576",
            ),
        ],
    )
    .command(
        "mnist",
        "Table II workload: online MNIST learning (synthetic corpus)",
        vec![
            opt("train", "training images", "300"),
            opt("test", "test images", "100"),
            opt("epochs", "training epochs", "3"),
            opt("hidden", "hidden width (paper: 1024)", "1024"),
            flag("pair-stdp", "use the fixed pair-STDP baseline rule"),
        ],
    )
    .command(
        "fpga-report",
        "Table I resource breakdown, power estimate and Fig. 4 floorplan",
        vec![
            flag("layout", "print the Fig. 4-style floorplan"),
            flag("mnist-geometry", "report for the 784-1024-10 instance"),
        ],
    )
    .command("artifacts", "list AOT artifacts", vec![])
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let p = parser();
    let args = match p.parse(&argv) {
        Ok(a) => a,
        Err(help) => {
            eprintln!("{help}");
            std::process::exit(2);
        }
    };
    let seed = args.get_u64("seed", 42);
    let code = match args.command.as_deref() {
        Some("train-rule") => cmd_train_rule(&args, seed),
        Some("adapt") => cmd_adapt(&args, seed),
        Some("serve") => cmd_serve(&args, seed),
        Some("mnist") => cmd_mnist(&args, seed),
        Some("fpga-report") => cmd_fpga_report(&args),
        Some("artifacts") => cmd_artifacts(),
        _ => {
            eprintln!("{}", p.help_text());
            2
        }
    };
    std::process::exit(code);
}

fn cmd_train_rule(args: &Args, seed: u64) -> i32 {
    let env: &'static str = Box::leak(args.get_or("env", "ant-dir").into_boxed_str());
    let kind = if args.flag("weights") {
        GenomeKind::Weights
    } else {
        GenomeKind::PlasticityRule
    };
    if family_of(env).is_none() {
        eprintln!("unknown env {env:?}");
        return 2;
    }
    let mut cfg = TrainConfig::paper(env, kind);
    cfg.generations = args.get_usize("generations", 50);
    cfg.pairs = args.get_usize("pairs", 16);
    cfg.hidden = args.get_usize("hidden", 128);
    cfg.seed = seed;
    cfg.verbose = !args.flag("quiet");
    let result = train_rule(&cfg);
    let out = std::path::PathBuf::from(args.get_or("out", "results/rule.bin"));
    let kind_str = if args.flag("weights") { "weights" } else { "rule" };
    if let Err(e) = genome_io::save(&out, env, kind_str, cfg.hidden, &result.genome) {
        eprintln!("save failed: {e}");
        return 1;
    }
    let last = result.history.last().unwrap();
    println!(
        "trained {kind_str} for {env}: final pop-mean fitness {:.3}, saved to {}",
        last.mean_fitness,
        out.display()
    );
    0
}

/// Map an env name to its artifact geometry.
fn geometry_of(env: &str) -> &'static str {
    match env {
        "ant-dir" | "ant" => "ant",
        "cheetah-vel" | "halfcheetah" => "cheetah",
        _ => "reacher",
    }
}

/// Resolve the deployed model from `--genome`/`--env`: the SNN
/// geometry, whether it deploys plastic (a rule genome) or fixed (a
/// weight genome), and the flat genome itself (empty = untrained zero
/// rule). Shared by [`load_backend`] and the chunked adaptation path,
/// which constructs its own per-chunk backends.
fn load_model(
    args: &Args,
    env: &str,
) -> Result<(firefly_p::snn::SnnConfig, bool, Vec<f32>), String> {
    let genome_path = std::path::PathBuf::from(args.get_or("genome", "results/rule.bin"));
    let (genome_env, kind_str, hidden, genome) = if genome_path.exists() {
        genome_io::load(&genome_path).map_err(|e| e.to_string())?
    } else {
        eprintln!(
            "note: genome file {} not found — deploying a zero (untrained) rule",
            genome_path.display()
        );
        (env.to_string(), "rule".to_string(), 128, Vec::new())
    };
    if !genome.is_empty() && genome_env != env {
        return Err(format!("genome was trained for {genome_env}, not {env}"));
    }
    let e = make_env(env).ok_or_else(|| format!("unknown env {env:?}"))?;
    let mut cfg = firefly_p::snn::SnnConfig::control(
        e.obs_dim() * firefly_p::es::eval::NEURONS_PER_DIM,
        2 * e.act_dim(),
    );
    cfg.n_hidden = hidden;
    Ok((cfg, kind_str == "rule", genome))
}

/// The plasticity rule a [`load_model`] result deploys: the genome when
/// it is a non-empty rule genome, the zero rule otherwise (untrained,
/// or a fixed-weight deployment that never consults θ). The single
/// definition both the backend loader and the chunked adaptation path
/// construct from.
fn deployed_rule(cfg: &firefly_p::snn::SnnConfig, plastic: bool, genome: &[f32]) -> NetworkRule {
    if plastic && !genome.is_empty() {
        NetworkRule::from_flat(cfg, genome)
    } else {
        NetworkRule::zeros(cfg)
    }
}

/// The `--prec` arithmetic domain (defaults to f32 when the command
/// doesn't declare the option).
fn parse_prec(args: &Args) -> Result<Precision, String> {
    Precision::parse(&args.get_or("prec", "f32"))
}

fn load_backend(
    args: &Args,
    env: &str,
    step_threads: usize,
) -> Result<Box<dyn SnnBackend>, String> {
    let kind = BackendKind::parse(&args.get_or("backend", "native"))
        .ok_or("backend must be native | xla | fpga")?;
    let prec = parse_prec(args)?;
    if prec != Precision::F32 && kind != BackendKind::Native {
        return Err(format!(
            "--prec {} applies to --backend native only (xla/fpga fix their own datapath)",
            prec.as_str()
        ));
    }
    let (cfg, plastic, genome) = load_model(args, env)?;
    let rule = deployed_rule(&cfg, plastic, &genome);
    let backend: Box<dyn SnnBackend> = match (kind, plastic) {
        (BackendKind::Native, true) => match prec {
            Precision::F32 => Box::new(NativeBackend::plastic_with_threads(cfg, rule, step_threads)),
            Precision::F16 => Box::new(TypedNativeBackend::<F16>::plastic_with_threads(
                cfg,
                rule,
                step_threads,
            )),
            Precision::Qfx => Box::new(TypedNativeBackend::<Qfx>::plastic_with_threads(
                cfg,
                rule,
                step_threads,
            )),
        },
        (BackendKind::Native, false) => match prec {
            Precision::F32 => Box::new(NativeBackend::fixed_with_threads(
                cfg,
                &genome,
                step_threads,
            )),
            Precision::F16 => Box::new(TypedNativeBackend::<F16>::fixed_with_threads(
                cfg,
                &genome,
                step_threads,
            )),
            Precision::Qfx => Box::new(TypedNativeBackend::<Qfx>::fixed_with_threads(
                cfg,
                &genome,
                step_threads,
            )),
        },
        (BackendKind::Fpga, true) => Box::new(FpgaBackend::plastic(cfg, rule, HwConfig::default())),
        (BackendKind::Fpga, false) => {
            Box::new(FpgaBackend::fixed(cfg, &genome, HwConfig::default()))
        }
        (BackendKind::Xla, true) => Box::new(XlaBackend::plastic(geometry_of(env), &rule)?),
        (BackendKind::Xla, false) => Box::new(XlaBackend::fixed(geometry_of(env), &genome)?),
    };
    Ok(backend)
}

fn cmd_adapt(args: &Args, seed: u64) -> i32 {
    let env = args.get_or("env", "ant-dir");
    let batch = args.get_usize("batch", 1).max(1);
    let grid = args.get_or("grid", "task");
    let kind = BackendKind::parse(&args.get_or("backend", "native"));
    let prec = match parse_prec(args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("bad --prec: {e}");
            return 2;
        }
    };
    // Adaptation parallelizes by *scenario chunk* (plant + network per
    // chunk), not by step: --adapt-threads picks the chunk count for
    // the native backend's chunked engine (0 = all CPU cores).
    let adapt_threads = match args.get_usize("adapt-threads", 1) {
        0 => firefly_p::util::threadpool::available_cores(),
        n => n,
    };
    let perturb_spec = args.get_or("perturb", "");
    let perturbation = if perturb_spec.is_empty() {
        None
    } else {
        match Perturbation::parse(&perturb_spec) {
            Ok(p) => Some(p),
            Err(e) => {
                eprintln!("bad --perturb: {e}");
                return 2;
            }
        }
    };
    let family = match family_of(&env) {
        Some(f) => f,
        None => {
            eprintln!("unknown env {env:?}");
            return 2;
        }
    };
    let perturb_at = args.get_usize("perturb-at", 100);
    let schedule_spec = args.get_or("perturb-schedule", "");

    // Single-episode path (the historical CLI shape). A non-empty
    // --perturb-schedule always routes through the batched engine so
    // the schedule is honored even at B = 1.
    if batch == 1 && grid == "task" && schedule_spec.is_empty() {
        let mut backend: Box<dyn SnnBackend> = match load_backend(args, &env, 1) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("{e}");
                return 1;
            }
        };
        let tasks = train_grid(family);
        let task = tasks[args.get_usize("task", 0).min(tasks.len() - 1)].clone();
        let cfg = AdaptConfig {
            env_name: env.clone(),
            perturbation,
            perturb_at,
            seed,
            window: 20,
        };
        let log = run_adaptation(backend.as_mut(), &cfg, &task);
        println!(
            "env={env} backend={} task={} total_reward={:.2} recovery_ratio={:.3}{}",
            backend.name(),
            task.id,
            log.total_reward,
            log.recovery_ratio(),
            match log.time_to_recover {
                Some(t) => format!(" time_to_recover={t}"),
                None => String::new(),
            }
        );
        return 0;
    }

    // Batched scenario-grid path: fan the task grid out over engine
    // runs of up to `batch` concurrent sessions each.
    let tasks = match grid.as_str() {
        "train" => train_grid(family),
        "eval" => eval_grid(family),
        "task" => {
            let all = train_grid(family);
            let t = all[args.get_usize("task", 0).min(all.len() - 1)].clone();
            vec![t; batch]
        }
        other => {
            eprintln!("--grid must be task | train | eval (got {other:?})");
            return 2;
        }
    };
    let schedule = match parse_schedule(&schedule_spec) {
        Ok(s) if s.is_empty() => match perturbation {
            Some(p) => vec![(Some(p), perturb_at)],
            None => Vec::new(),
        },
        Ok(s) => s,
        Err(e) => {
            eprintln!("bad --perturb-schedule: {e}");
            return 2;
        }
    };
    let mut scenarios = scenarios_for_grid(&tasks, &schedule, seed);
    if grid == "task" {
        // Replicated single task: decorrelate the sessions by seed so
        // the batch explores B independent episodes.
        for (s, sc) in scenarios.iter_mut().enumerate() {
            sc.seed = seed.wrapping_add(s as u64);
        }
    }
    let cfg = BatchAdaptConfig {
        env_name: env.clone(),
        window: 20,
        max_steps: None,
    };
    let mut logs = Vec::with_capacity(scenarios.len());
    let mut metrics = Metrics::new();
    let backend_name;
    // What actually ran, for the report line: the replicated fallback
    // ignores --adapt-threads and steps on one thread.
    let mut effective_threads = adapt_threads;
    let t0 = std::time::Instant::now();
    if kind == Some(BackendKind::Native) {
        // Scenario-sharded chunked engine: the grid fans out over
        // engine runs of up to `batch` sessions, each run partitioned
        // into `adapt_threads` per-core chunks (plant + network both
        // parallel) whose plastic backends all share one
        // Arc<NetworkRule> θ allocation. Bit-identical to the inline
        // engine at any thread count (tests/batch_adapt_equivalence.rs).
        backend_name = "native";
        // Each engine run hosts at most `batch` concurrent sessions, so
        // a run can never spread across more than `batch` chunks —
        // surface the cap instead of silently reporting the requested
        // thread count against serial throughput.
        effective_threads = adapt_threads.clamp(1, batch);
        if effective_threads < adapt_threads {
            eprintln!(
                "note: --adapt-threads {adapt_threads} capped to --batch {batch} \
                 (each engine run hosts at most --batch concurrent sessions; \
                 raise --batch to use more cores)"
            );
        }
        let (net_cfg, plastic, genome) = match load_model(args, &env) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("{e}");
                return 1;
            }
        };
        let rule = Arc::new(deployed_rule(&net_cfg, plastic, &genome));
        let spec = if plastic {
            ChunkBackendSpec::Plastic(rule)
        } else {
            ChunkBackendSpec::Fixed(&genome)
        };
        // One fresh engine (pool + per-chunk backends) per slice: the
        // setup is cold-path — amortized over a full episode horizon of
        // ticks per run — and fresh per-chunk backends start episodes
        // from exactly the state the old reused-backend loop produced
        // via per-session resets.
        for chunk in scenarios.chunks(batch) {
            // --prec selects the chunk backends' scalar domain; the
            // engine and schedule are identical across all three.
            let run = match prec {
                Precision::F32 => run_chunked_adaptation::<f32>(
                    &net_cfg,
                    spec.clone(),
                    &cfg,
                    chunk,
                    effective_threads,
                ),
                Precision::F16 => run_chunked_adaptation::<F16>(
                    &net_cfg,
                    spec.clone(),
                    &cfg,
                    chunk,
                    effective_threads,
                ),
                Precision::Qfx => run_chunked_adaptation::<Qfx>(
                    &net_cfg,
                    spec.clone(),
                    &cfg,
                    chunk,
                    effective_threads,
                ),
            };
            // Per-run registries merge in chunk order: the aggregate
            // report is independent of batch size and thread count.
            let mut m = Metrics::new();
            GridSummary::observe_logs(&mut m, &run);
            metrics.absorb(m);
            logs.extend(run);
        }
    } else {
        // xla/fpga: single-session backends serve wider batches through
        // the ReplicatedBackend fallback (one instance per session —
        // correct, not batched), stepped by the inline engine on the
        // caller thread. The chunked engine cannot construct per-chunk
        // replicas of these backends, so --adapt-threads is native-only.
        if adapt_threads > 1 {
            eprintln!(
                "note: --adapt-threads applies to --backend native only; \
                 running the replicated engine single-threaded"
            );
        }
        effective_threads = 1;
        let mut backend: Box<dyn SnnBackend> = if batch == 1 {
            match load_backend(args, &env, 1) {
                Ok(b) => b,
                Err(e) => {
                    eprintln!("{e}");
                    return 1;
                }
            }
        } else {
            let mut instances = Vec::with_capacity(batch);
            for _ in 0..batch {
                match load_backend(args, &env, 1) {
                    Ok(b) => instances.push(b),
                    Err(e) => {
                        eprintln!("{e}");
                        return 1;
                    }
                }
            }
            Box::new(ReplicatedBackend::from_instances(instances))
        };
        backend_name = backend.name();
        for chunk in scenarios.chunks(batch) {
            let run = run_batch_adaptation(backend.as_mut(), &cfg, chunk);
            let mut m = Metrics::new();
            GridSummary::observe_logs(&mut m, &run);
            metrics.absorb(m);
            logs.extend(run);
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let total_steps: usize = logs.iter().map(|l| l.rewards.len()).sum();
    let summary = GridSummary::from_logs(&logs);
    println!(
        "env={env} backend={backend_name} prec={} grid={grid} sessions={} batch={batch} \
         adapt_threads={effective_threads} steps_per_s={:.0} mean_reward={:.2} \
         mean_recovery={:.3} recovered={}/{} time_to_recover_p50={:.1}",
        prec.as_str(),
        summary.sessions,
        total_steps as f64 / elapsed.max(1e-9),
        summary.mean_total_reward,
        summary.mean_recovery_ratio,
        summary.recovered,
        summary.perturbed,
        summary.time_to_recover_p50,
    );
    print!("{}", metrics.report());
    0
}

fn cmd_serve(args: &Args, seed: u64) -> i32 {
    let env = args.get_or("env", "cheetah-vel");
    let e = match make_env(&env) {
        Some(e) => e,
        None => {
            eprintln!("unknown env {env:?}");
            return 2;
        }
    };
    let (obs_dim, act_dim) = (e.obs_dim(), e.act_dim());
    let sessions = args.get_usize("sessions", 16).max(1);
    // Shard count of the native batched stepper: one 64-lane word shard
    // per worker thread, default = all CPU cores (DESIGN.md §Hot-Path).
    let step_threads = match args.get_usize("step-threads", 0) {
        0 => firefly_p::util::threadpool::available_cores(),
        n => n,
    };
    let kind = BackendKind::parse(&args.get_or("backend", "xla"));
    // The native backend batches sessions in sharded SoA networks; the
    // single-session backends (xla, fpga) are replicated — one instance
    // per session, stepped in a loop (correct fallback, no batching).
    let backend: Box<dyn SnnBackend> = if kind == Some(BackendKind::Native) || sessions == 1 {
        match load_backend(args, &env, step_threads) {
            Ok(b) => b,
            Err(err) => {
                eprintln!("{err}");
                return 1;
            }
        }
    } else {
        let mut instances = Vec::with_capacity(sessions);
        for _ in 0..sessions {
            match load_backend(args, &env, 1) {
                Ok(b) => instances.push(b),
                Err(err) => {
                    eprintln!("{err}");
                    return 1;
                }
            }
        }
        Box::new(ReplicatedBackend::from_instances(instances))
    };
    let read_timeout_ms = args.get_usize("read-timeout-ms", 0);
    let tick_deadline_us = args.get_usize("tick-deadline-us", 0);
    // Durable serving plane: snapshots land in --state-dir at tick
    // boundaries; on restart the newest valid one warm-starts every
    // session and clients re-attach with RESUME <token>.
    let state_dir = args.get_or("state-dir", "");
    let state_dir = (!state_dir.is_empty()).then(|| std::path::PathBuf::from(state_dir));
    if let Some(dir) = &state_dir {
        if let Err(e) = std::fs::create_dir_all(dir) {
            eprintln!("state-dir {}: {e}", dir.display());
            return 1;
        }
    }
    let mut server = ControlServer::with_config(
        backend,
        obs_dim,
        act_dim,
        ServerConfig {
            max_sessions: sessions,
            seed,
            max_line: args.get_usize("line-cap", 64 * 1024).max(16),
            read_timeout: (read_timeout_ms > 0)
                .then(|| std::time::Duration::from_millis(read_timeout_ms as u64)),
            tick_deadline: (tick_deadline_us > 0)
                .then(|| std::time::Duration::from_micros(tick_deadline_us as u64)),
            state_dir,
            snapshot_every: args.get_usize("snapshot-every-ticks", 16).max(1) as u64,
            follower_lag_cap: args.get_usize("stream-lag-cap", 1 << 20).max(1),
        },
    );
    // Adaptation-as-a-service: JOB verbs run grid sweeps on dedicated
    // runner threads (never the serving path). --job-threads 0 leaves
    // the subsystem detached and the verbs answer `ERR job-disabled`.
    let job_threads = args.get_usize("job-threads", 1);
    if job_threads > 0 {
        let job_dir = args.get_or("job-dir", "");
        let job_dir = (!job_dir.is_empty()).then(|| std::path::PathBuf::from(job_dir));
        if let Some(dir) = &job_dir {
            if let Err(e) = std::fs::create_dir_all(dir) {
                eprintln!("job-dir {}: {e}", dir.display());
                return 1;
            }
        }
        let admission_wait_ms = args.get_usize("admission-wait-ms", 0);
        let jobs = Arc::new(JobManager::with_metrics(
            JobManagerConfig {
                queue_cap: args.get_usize("job-queue", 8).max(1),
                runners: job_threads,
                job_dir,
                fair_share: args.flag("fair-share"),
                admission_wait: (admission_wait_ms > 0)
                    .then(|| std::time::Duration::from_millis(admission_wait_ms as u64)),
                ..Default::default()
            },
            server.metrics(),
        ));
        // Pin the deployed model as the job-side θ snapshot source for
        // the serve env's family.
        match load_model(args, &env) {
            Ok((cfg, plastic, genome)) => {
                let model = if plastic {
                    JobModel::plastic(cfg.clone(), deployed_rule(&cfg, plastic, &genome))
                } else {
                    JobModel::fixed(cfg, genome)
                };
                if let Err(e) = jobs.install_model(&env, model) {
                    eprintln!("job model: {e}");
                    return 1;
                }
            }
            Err(err) => {
                eprintln!("{err}");
                return 1;
            }
        }
        // Crash recovery: re-admit interrupted sweeps from --job-dir
        // (each checkpoint carries its own θ snapshot, independent of
        // the model installed above). Corrupt files are quarantined as
        // `.corrupt`, never a panic.
        if jobs.job_dir().is_some() {
            let report = jobs.recover();
            if !report.resumed.is_empty() || report.quarantined > 0 || report.rejected > 0 {
                eprintln!(
                    "job recovery: resumed {} job(s) {:?}, quarantined {}, rejected {}",
                    report.resumed.len(),
                    report.resumed,
                    report.quarantined,
                    report.rejected,
                );
            }
        }
        server.attach_jobs(jobs);
    }
    let addr = args.get_or("addr", "127.0.0.1:7690");
    if let Err(err) = server.serve(&addr, None) {
        eprintln!("server: {err}");
        return 1;
    }
    0
}

fn cmd_mnist(args: &Args, seed: u64) -> i32 {
    let train = mnist::generate(args.get_usize("train", 300), seed);
    let test = mnist::generate(args.get_usize("test", 100), seed ^ 0xFF);
    let rule = if args.flag("pair-stdp") {
        mnist::UpdateRule::pair_stdp_default()
    } else {
        mnist::UpdateRule::learnable_default()
    };
    let mut cfg = mnist::MnistConfig {
        hidden: args.get_usize("hidden", 1024),
        seed,
        ..Default::default()
    };
    cfg.k_winners = (cfg.hidden / 32).max(4);
    let mut m = mnist::OnlineMnist::new(cfg, rule);
    for e in 0..args.get_usize("epochs", 3) {
        m.train_epoch(&train);
        println!("epoch {e}: accuracy {:.3}", m.accuracy(&test));
    }
    0
}

fn cmd_fpga_report(args: &Args) -> i32 {
    let hw = HwConfig::default();
    let geo = if args.flag("mnist-geometry") {
        NetGeometry::mnist()
    } else {
        NetGeometry::paper_control()
    };
    let report = ResourceReport::build(&hw, &geo);
    println!("=== Table I — resource breakdown ===");
    print!("{}", report.render());
    let power = PowerModel::new(report.clone()).estimate(&Activity::nominal());
    println!("\n=== Power (nominal activity) ===\n{}", power.render());
    if args.flag("layout") {
        println!("\n=== Fig. 4 — implemented design layout ===");
        print!("{}", layout::render_floorplan(&report));
    }
    0
}

fn cmd_artifacts() -> i32 {
    match Registry::open_default() {
        Ok(reg) => {
            println!("artifacts in {}:", reg.dir.display());
            for m in reg.list() {
                println!(
                    "  {}_{}  ({}-{}-{})  {}",
                    m.name,
                    m.variant,
                    m.n_in,
                    m.n_hidden,
                    m.n_out,
                    m.hlo_path.display()
                );
            }
            0
        }
        Err(e) => {
            eprintln!("{e}");
            1
        }
    }
}
