//! Baselines the paper compares against.
//!
//! - [`weight_trained`]: SNNs with directly evolved synaptic weights and
//!   no online plasticity — Fig. 3's comparator ("SNNs with directly
//!   trained synaptic weights").
//! - [`stdp`]: classic fixed plasticity rules (pair-based STDP, and a
//!   [16]-style triplet variant) — Table II's prior-work learning rules,
//!   plus the rows of published systems for the rendered table.

pub mod stdp;
pub mod weight_trained;

pub use stdp::{PairStdpRule, TripletStdpRule};
pub use weight_trained::train_weight_baseline;
