//! The weight-trained baseline of Fig. 3: identical SNN architecture,
//! identical PEPG optimizer, identical task grid and budget — but the
//! genome is the synaptic weight vector itself and **no online
//! adaptation happens at deployment**. The comparison isolates exactly
//! one variable: whether the evolved object is a *learning rule* or a
//! *weight configuration*.

use crate::coordinator::offline::{train_rule, TrainConfig, TrainResult};
use crate::es::eval::GenomeKind;

/// Train the weight baseline with a budget mirrored from `rule_cfg`.
pub fn train_weight_baseline(rule_cfg: &TrainConfig) -> TrainResult {
    let mut cfg = rule_cfg.clone();
    cfg.kind = GenomeKind::Weights;
    train_rule(&cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::offline::TrainConfig;
    use crate::es::eval::{rollout_fitness, EvalSpec, GenomeKind};

    #[test]
    fn baseline_trains_and_deploys_fixed() {
        let mut cfg = TrainConfig::quick("cheetah-vel", GenomeKind::PlasticityRule);
        cfg.generations = 5;
        let result = train_weight_baseline(&cfg);
        // genome is a weight vector, evaluable under Weights semantics
        let spec = EvalSpec {
            kind: GenomeKind::Weights,
            ..cfg.spec()
        };
        assert_eq!(result.genome.len(), spec.genome_dim());
        let fit = rollout_fitness(&spec, &result.genome);
        assert!(fit.is_finite());
    }
}
