//! Fixed plasticity-rule baselines (the learning rules of Table II's
//! prior systems), expressed in the same θ parameterization where
//! possible so they run on the identical engines.
//!
//! Pair-based STDP with traces,
//!
//! ```text
//! Δw = A⁺·S_j·s_i − A⁻·S_i·s_j
//! ```
//!
//! is *not* a special case of the four-term rule (it gates on the spike
//! indicators s, not the traces S), which is precisely the expressivity
//! gap the learnable rule exploits. We therefore provide the honest
//! event-gated implementations here and, for the ablation bench, the
//! best *trace-approximated* projection onto θ.

use crate::util::rng::Pcg64;

/// Classic pair-based STDP (Table II rows [35], [37]).
#[derive(Clone, Copy, Debug)]
pub struct PairStdpRule {
    /// Potentiation gain on a postsynaptic spike.
    pub a_plus: f32,
    /// Depression gain on a presynaptic spike.
    pub a_minus: f32,
}

impl Default for PairStdpRule {
    fn default() -> Self {
        PairStdpRule {
            a_plus: 0.6,
            a_minus: 0.3,
        }
    }
}

impl PairStdpRule {
    /// Event-gated update for one synapse.
    #[inline]
    pub fn delta(&self, pre_trace: f32, post_trace: f32, pre_spike: bool, post_spike: bool) -> f32 {
        let mut dw = 0.0;
        if post_spike {
            dw += self.a_plus * pre_trace;
        }
        if pre_spike {
            dw -= self.a_minus * post_trace;
        }
        dw
    }

    /// Trace-approximated projection onto the four-term θ: spikes are
    /// replaced by their expectation given the trace (s ≈ (1−λ)·S for a
    /// stationary rate), giving α = A⁺(1−λ) − A⁻(1−λ), β = γ = δ = 0.
    /// Used by the ablation bench to quantify what the approximation
    /// loses.
    pub fn theta_projection(&self, lambda: f32) -> [f32; 4] {
        let g = 1.0 - lambda;
        [(self.a_plus - self.a_minus) * g, 0.0, 0.0, 0.0]
    }
}

/// Triplet STDP (Pfister & Gerstner 2006 — reference [16]; Table II row
/// [39] uses the reward-modulated variant). Adds a second, slower
/// postsynaptic trace so potentiation depends on post-spike history.
#[derive(Clone, Debug)]
pub struct TripletStdpRule {
    /// Pair-term potentiation gain.
    pub a2_plus: f32,
    /// Pair-term depression gain.
    pub a2_minus: f32,
    /// Triplet-term potentiation gain (scaled by the slow trace).
    pub a3_plus: f32,
    /// Slow postsynaptic trace state (per neuron) and its decay.
    pub lambda_slow: f32,
    slow_post: Vec<f32>,
}

impl TripletStdpRule {
    /// Reference operating point with `n_post` slow postsynaptic traces.
    pub fn new(n_post: usize) -> TripletStdpRule {
        TripletStdpRule {
            a2_plus: 0.5,
            a2_minus: 0.3,
            a3_plus: 0.4,
            lambda_slow: 0.8,
            slow_post: vec![0.0; n_post],
        }
    }

    /// Advance the slow traces (call once per timestep after spikes).
    pub fn tick(&mut self, post_spikes: &[bool]) {
        for (t, &s) in self.slow_post.iter_mut().zip(post_spikes) {
            *t = self.lambda_slow * *t + if s { 1.0 } else { 0.0 };
        }
    }

    /// Event-gated update for one synapse onto postsynaptic neuron
    /// `i_post`.
    #[inline]
    pub fn delta(
        &self,
        i_post: usize,
        pre_trace: f32,
        post_trace: f32,
        pre_spike: bool,
        post_spike: bool,
    ) -> f32 {
        let mut dw = 0.0;
        if post_spike {
            // pair + triplet potentiation (gated by the slow trace)
            dw += pre_trace * (self.a2_plus + self.a3_plus * self.slow_post[i_post]);
        }
        if pre_spike {
            dw -= self.a2_minus * post_trace;
        }
        dw
    }
}

/// Smoke-level behavioural check helper: run a Poisson pre/post pair
/// under a rule and report the net drift (used by tests to verify the
/// causal-potentiation signature of STDP).
pub fn pair_drift(rule: &PairStdpRule, causal: bool, steps: usize, seed: u64) -> f32 {
    let mut rng = Pcg64::new(seed, 0);
    let (mut s_pre, mut s_post) = (0.0f32, 0.0f32);
    let mut w = 0.0f32;
    let lam = 0.5;
    for _ in 0..steps {
        let pre = rng.bernoulli(0.3);
        // causal: post tends to follow pre; anti-causal: post leads.
        let post = if causal {
            s_pre > 0.4 && rng.bernoulli(0.8)
        } else {
            rng.bernoulli(0.3)
        };
        s_pre = lam * s_pre + if pre { 1.0 } else { 0.0 };
        s_post = lam * s_post + if post { 1.0 } else { 0.0 };
        w += 0.05 * rule.delta(s_pre, s_post, pre, post);
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn causal_firing_potentiates() {
        let rule = PairStdpRule::default();
        let causal = pair_drift(&rule, true, 2000, 1);
        let random = pair_drift(&rule, false, 2000, 1);
        assert!(
            causal > random,
            "causal drift {causal} must exceed random {random}"
        );
    }

    #[test]
    fn depression_dominates_uncorrelated_high_rate() {
        // With A⁻ balanced against A⁺ and uncorrelated firing, pre-spike
        // depression events accumulate (classic STDP stability story).
        let rule = PairStdpRule {
            a_plus: 0.3,
            a_minus: 0.6,
        };
        let drift = pair_drift(&rule, false, 3000, 2);
        assert!(drift < 0.0, "drift {drift}");
    }

    #[test]
    fn theta_projection_shape() {
        let rule = PairStdpRule::default();
        let theta = rule.theta_projection(0.5);
        assert!((theta[0] - 0.15).abs() < 1e-6);
        assert_eq!(&theta[1..], &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn triplet_slow_trace_gates_potentiation() {
        let mut rule = TripletStdpRule::new(1);
        // no post history: only pair potentiation
        let base = rule.delta(0, 1.0, 0.0, false, true);
        // build post history
        for _ in 0..5 {
            rule.tick(&[true]);
        }
        let gated = rule.delta(0, 1.0, 0.0, false, true);
        assert!(gated > base, "triplet term must add potentiation");
    }
}
