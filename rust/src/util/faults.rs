//! Deterministic fault injection for the robustness suite (ISSUE 7;
//! DESIGN.md §Durability-and-Faults).
//!
//! A [`FaultPlan`] names *sites* (places in the job/serving planes that
//! agreed to be breakable) and, per site, the exact occurrence indices
//! at which the fault fires. Hook code calls [`FaultPlan::fire`] at the
//! site; the plan counts the visit and answers whether this particular
//! visit is the one that fails. Plans are either spelled out explicitly
//! ([`FaultPlan::at`], the conformance tests' mode — "kill after the
//! k-th checkpoint") or derived from a seed ([`FaultPlan::seeded`],
//! soak-style sweeps) — both fully deterministic, so a failing fault
//! run reproduces from its seed alone.
//!
//! Production code paths carry an `Option<Arc<FaultPlan>>` that is
//! `None` outside tests/benches; the hook then costs one branch on a
//! runner/handler thread (never the serving hot path).

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::util::rng::Pcg64;

/// A place that agreed to be breakable.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSite {
    /// Panic inside a job runner right before the sweep's engine work
    /// (exercises `catch_unwind` → typed `Failed` containment).
    RunnerPanic = 0,
    /// IO error out of a durable checkpoint write (exercises the
    /// degrade-to-in-memory path).
    CheckpointWrite = 1,
    /// Server drops the connection mid `JOB RESULTS` stream (exercises
    /// slot reclamation with the job left running).
    StreamCut = 2,
    /// Job runner halts (state `Interrupted`) right after persisting a
    /// batch-aligned checkpoint — the crash-recovery conformance
    /// tests' deterministic "kill -9 at the k-th batch boundary".
    InterruptAfterBatch = 3,
    /// Server drops a `JOB SUBSCRIBE` follower mid-push (exercises the
    /// cursor-resume path: the cut subscriber reconnects with
    /// `from=<cursor>` and must see the remaining rows bit-identically,
    /// with nothing lost or duplicated).
    SubscriberCut = 4,
    /// Serving stepper treats the tick as a deadline overrun even if the
    /// wall clock was fine (exercises the load-shedding state machine —
    /// drop to fixed-weights stepping, then restore — deterministically,
    /// independent of host speed).
    OverloadBurst = 5,
    /// Runner-pool scheduler stalls briefly before dispatching the next
    /// job (exercises queue aging / deadline-aware admission under a
    /// slow scheduler).
    SchedulerDelay = 6,
    /// IO error out of a durable serving-snapshot write (exercises the
    /// serving plane's degrade-to-in-memory path: snapshotting disables
    /// itself with a logged warning and a `serve_snapshot_write_errors`
    /// count, the stepper never stalls).
    SnapshotWrite = 7,
    /// Serving-snapshot write lands torn: only a truncated prefix of
    /// the frame reaches disk (simulating a crash mid-write on a
    /// filesystem without atomic rename semantics). Recovery must
    /// quarantine the torn file as `*.corrupt` and fall back to the
    /// newest intact snapshot.
    SnapshotTorn = 8,
    /// A `JOB SUBSCRIBE` follower stops draining its socket (exercises
    /// hub-side flow control: the bounded outbound queue overflows and
    /// the hub evicts the follower with `ERR lagged next=<row>` instead
    /// of buffering without bound or delaying its siblings).
    FollowerStall = 9,
}

const N_SITES: usize = 10;

const ALL_SITES: [FaultSite; N_SITES] = [
    FaultSite::RunnerPanic,
    FaultSite::CheckpointWrite,
    FaultSite::StreamCut,
    FaultSite::InterruptAfterBatch,
    FaultSite::SubscriberCut,
    FaultSite::OverloadBurst,
    FaultSite::SchedulerDelay,
    FaultSite::SnapshotWrite,
    FaultSite::SnapshotTorn,
    FaultSite::FollowerStall,
];

#[derive(Debug, Default)]
struct SiteState {
    /// Sorted occurrence indices at which the site fires.
    at: Vec<usize>,
    /// Visits so far (every `fire` call, firing or not).
    hits: AtomicUsize,
    /// Visits that actually fired.
    fired: AtomicUsize,
}

/// A deterministic schedule of injected faults. Cheap to share behind
/// an `Arc`; all counters are atomic, so concurrent runners hitting the
/// same site each observe a unique occurrence index.
#[derive(Debug, Default)]
pub struct FaultPlan {
    sites: [SiteState; N_SITES],
}

impl FaultPlan {
    /// A plan that never fires (hooks still count visits).
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Builder: fire `site` at exactly these occurrence indices
    /// (0-based over that site's `fire` calls).
    pub fn at(mut self, site: FaultSite, occurrences: &[usize]) -> FaultPlan {
        let st = &mut self.sites[site as usize];
        st.at.extend_from_slice(occurrences);
        st.at.sort_unstable();
        st.at.dedup();
        self
    }

    /// A seeded plan: each site independently fires each of its first
    /// `horizon` occurrences with probability `rate`, from its own
    /// deterministic stream — same seed, same plan, every run.
    pub fn seeded(seed: u64, horizon: usize, rate: f64) -> FaultPlan {
        FaultPlan::new().seeded_at(seed, horizon, rate, &ALL_SITES)
    }

    /// Builder: seed only the listed `sites`, each from the same
    /// per-site stream [`FaultPlan::seeded`] uses (`Pcg64::new(seed,
    /// 0xFA17 ^ site)`), so restricting the site list never perturbs the
    /// surviving sites' schedules. Soak harnesses use this to randomize
    /// only the sites they can actually recover from, with per-site
    /// horizons (chain calls) matched to each site's visit budget —
    /// a schedule that outruns a site's visits would trip
    /// [`FaultPlan::assert_exhausted`].
    pub fn seeded_at(
        mut self,
        seed: u64,
        horizon: usize,
        rate: f64,
        sites: &[FaultSite],
    ) -> FaultPlan {
        for &site in sites {
            let mut rng = Pcg64::new(seed, 0xFA17 ^ site as u64);
            let at: Vec<usize> = (0..horizon).filter(|_| rng.bernoulli(rate)).collect();
            self = self.at(site, &at);
        }
        self
    }

    /// Visit `site`: record the hit and return whether this occurrence
    /// is scheduled to fail. The caller performs the actual fault
    /// (panic, `Err`, disconnect) so the blast shape stays in the code
    /// under test, not in the plan.
    pub fn fire(&self, site: FaultSite) -> bool {
        let st = &self.sites[site as usize];
        let k = st.hits.fetch_add(1, Ordering::SeqCst);
        let hit = st.at.binary_search(&k).is_ok();
        if hit {
            st.fired.fetch_add(1, Ordering::SeqCst);
        }
        hit
    }

    /// Total visits to `site` so far.
    pub fn hits(&self, site: FaultSite) -> usize {
        self.sites[site as usize].hits.load(Ordering::SeqCst)
    }

    /// Visits to `site` that fired.
    pub fn fired(&self, site: FaultSite) -> usize {
        self.sites[site as usize].fired.load(Ordering::SeqCst)
    }

    /// Sites with scheduled occurrences that have not all fired yet,
    /// with how many remain unfired per site. A soak plan that drains
    /// to the empty vec proved every scheduled fault actually executed;
    /// anything left over means the schedule silently outran the run.
    pub fn unexhausted(&self) -> Vec<(FaultSite, usize)> {
        ALL_SITES
            .iter()
            .filter_map(|&site| {
                let scheduled = self.sites[site as usize].at.len();
                let fired = self.fired(site);
                (fired < scheduled).then_some((site, scheduled - fired))
            })
            .collect()
    }

    /// Occurrence-exhaustion guard: panic unless every scheduled fault
    /// fired. Soak tests call this at the end of the run so a plan that
    /// never reaches its last site is a test failure, not a silent pass.
    pub fn assert_exhausted(&self) {
        let left = self.unexhausted();
        assert!(
            left.is_empty(),
            "fault plan not exhausted — unfired occurrences remain: {left:?}"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn explicit_plan_fires_exactly_at_scheduled_occurrences() {
        let plan = FaultPlan::new().at(FaultSite::RunnerPanic, &[0, 2, 2, 5]);
        let fired: Vec<bool> = (0..8).map(|_| plan.fire(FaultSite::RunnerPanic)).collect();
        assert_eq!(
            fired,
            [true, false, true, false, false, true, false, false]
        );
        assert_eq!(plan.hits(FaultSite::RunnerPanic), 8);
        assert_eq!(plan.fired(FaultSite::RunnerPanic), 3);
        // Other sites are untouched.
        assert!(!plan.fire(FaultSite::CheckpointWrite));
        assert_eq!(plan.fired(FaultSite::CheckpointWrite), 0);
    }

    #[test]
    fn empty_plan_never_fires() {
        let plan = FaultPlan::new();
        assert!((0..100).all(|_| !plan.fire(FaultSite::StreamCut)));
    }

    #[test]
    fn seeded_plans_are_reproducible_and_seed_sensitive() {
        let a = FaultPlan::seeded(11, 1000, 0.1);
        let b = FaultPlan::seeded(11, 1000, 0.1);
        let c = FaultPlan::seeded(12, 1000, 0.1);
        let series = |p: &FaultPlan| -> Vec<bool> {
            (0..1000).map(|_| p.fire(FaultSite::CheckpointWrite)).collect()
        };
        let (sa, sb, sc) = (series(&a), series(&b), series(&c));
        assert_eq!(sa, sb, "same seed must give the same fault schedule");
        assert_ne!(sa, sc, "different seeds must diverge");
        let rate = sa.iter().filter(|&&f| f).count() as f64 / 1000.0;
        assert!((0.05..0.2).contains(&rate), "rate {rate} far from 0.1");
    }

    #[test]
    fn exhaustion_guard_flags_unfired_occurrences() {
        let plan = FaultPlan::new()
            .at(FaultSite::SubscriberCut, &[0, 3])
            .at(FaultSite::SchedulerDelay, &[1]);
        assert_eq!(
            plan.unexhausted(),
            [(FaultSite::SubscriberCut, 2), (FaultSite::SchedulerDelay, 1)]
        );
        // Fire SubscriberCut through occurrence 3 but never visit
        // SchedulerDelay enough: still unexhausted.
        for _ in 0..4 {
            plan.fire(FaultSite::SubscriberCut);
        }
        assert_eq!(plan.unexhausted(), [(FaultSite::SchedulerDelay, 1)]);
        plan.fire(FaultSite::SchedulerDelay); // occurrence 0: not scheduled
        assert_eq!(plan.unexhausted(), [(FaultSite::SchedulerDelay, 1)]);
        plan.fire(FaultSite::SchedulerDelay); // occurrence 1: fires
        assert!(plan.unexhausted().is_empty());
        plan.assert_exhausted();
    }

    #[test]
    #[should_panic(expected = "fault plan not exhausted")]
    fn assert_exhausted_panics_on_unfired_plan() {
        let plan = FaultPlan::new().at(FaultSite::OverloadBurst, &[5]);
        plan.fire(FaultSite::OverloadBurst);
        plan.assert_exhausted();
    }

    #[test]
    fn new_sites_do_not_perturb_existing_seeded_streams() {
        // Each site derives its stream from `seed ^ (0xFA17 ^ site)`,
        // so growing the site list must leave the original four sites'
        // schedules byte-identical (crash-recovery seeds stay valid).
        let plan = FaultPlan::seeded(11, 1000, 0.1);
        let mut rng = Pcg64::new(11, 0xFA17 ^ FaultSite::CheckpointWrite as u64);
        let expect: Vec<bool> = (0..1000).map(|_| rng.bernoulli(0.1)).collect();
        let got: Vec<bool> = (0..1000).map(|_| plan.fire(FaultSite::CheckpointWrite)).collect();
        assert_eq!(got, expect);
    }

    #[test]
    fn seeded_subset_matches_full_seeded_streams_and_leaves_rest_unarmed() {
        // A site-restricted seeded plan must schedule the listed sites
        // byte-identically to the all-sites plan (per-site streams are
        // independent) and leave every unlisted site empty.
        let full = FaultPlan::seeded(11, 200, 0.15);
        let sub = FaultPlan::new().seeded_at(
            11,
            200,
            0.15,
            &[FaultSite::SubscriberCut, FaultSite::SchedulerDelay],
        );
        for site in [FaultSite::SubscriberCut, FaultSite::SchedulerDelay] {
            let a: Vec<bool> = (0..200).map(|_| full.fire(site)).collect();
            let b: Vec<bool> = (0..200).map(|_| sub.fire(site)).collect();
            assert_eq!(a, b, "{site:?} schedule diverged from FaultPlan::seeded");
        }
        assert!(
            (0..200).all(|_| !sub.fire(FaultSite::RunnerPanic)),
            "unlisted sites must never fire"
        );
        sub.assert_exhausted();
    }

    #[test]
    fn concurrent_fire_counts_every_visit_once() {
        let plan = std::sync::Arc::new(FaultPlan::new().at(FaultSite::RunnerPanic, &[10, 20, 30]));
        let total_fired: usize = std::thread::scope(|s| {
            (0..4)
                .map(|_| {
                    let plan = std::sync::Arc::clone(&plan);
                    s.spawn(move || (0..25).filter(|_| plan.fire(FaultSite::RunnerPanic)).count())
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .sum()
        });
        assert_eq!(plan.hits(FaultSite::RunnerPanic), 100);
        assert_eq!(total_fired, 3, "each scheduled occurrence fires exactly once");
    }
}
