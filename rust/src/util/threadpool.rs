//! Fixed-size thread pool over per-worker mailboxes (the offline
//! registry has no tokio/rayon). Used by the ES leader to fan population
//! rollouts out to worker threads, by the Fig-3 benchmark to run seeds
//! in parallel, by the sharded batched stepper
//! ([`crate::snn::ShardedNetwork`]) to drive per-shard network steps
//! across cores, and by the chunked adaptation engine
//! ([`crate::coordinator::batch_adapt::ChunkedAdaptEngine`]) to step
//! whole scenario chunks (plant + network) in parallel.
//!
//! Design: a scoped map — `map_indexed` takes a slice of inputs and a
//! worker function and returns outputs in input order. Workers pull
//! indices from a shared atomic counter (work stealing by chunk of 1),
//! which balances heterogeneous rollout lengths well.
//!
//! For repeated dispatch the persistent [`ThreadPool`] additionally
//! offers [`ThreadPool::scope`]: spawn **borrowing** jobs (non-`'static`
//! closures over caller state, e.g. per-shard disjoint `&mut` slices)
//! onto the pool's workers and join them all before the scope returns —
//! the pool-backed analogue of `std::thread::scope`, without re-spawning
//! OS threads every tick.
//!
//! # Pooled job boxes (alloc-free scope dispatch)
//!
//! Scope jobs are not boxed per dispatch. Each worker owns a one-deep
//! **mailbox slot** backed by a reusable raw capture buffer: `spawn_on`
//! writes the closure's capture in place (the buffer's capacity and the
//! scratch the worker moves it into persist across calls), so a
//! steady-state multi-shard / multi-chunk tick performs **zero heap
//! allocations** for dispatch once the first tick has sized the buffers
//! (pinned by `tests/alloc_free_serving.rs`). Fire-and-forget `'static`
//! jobs ([`ThreadPool::execute`] / [`ThreadPool::execute_on`]) still box
//! into a per-worker queue — that path serves connection handlers and ES
//! generations, not per-tick dispatch.
//!
//! Two panic policies exist for queued jobs: the loud default
//! ([`ThreadPool::new`] — a dead worker fails later dispatch, the
//! compute pools' bug-surfacing contract) and self-healing
//! ([`ThreadPool::respawning`] — a panicking job's worker is replaced on
//! the same mailbox, the serving plane's containment contract).

use std::alloc::Layout;
use std::any::Any;
use std::collections::VecDeque;
use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Number of worker threads to use by default: physical parallelism,
/// capped to leave a core for the coordinator.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(4)
}

/// Number of hardware threads available (no coordinator-core reserve) —
/// the default shard count of the batched serving stepper
/// (`--step-threads`) and the default chunk count of the batched
/// adaptation engine (`--adapt-threads 0`).
pub fn available_cores() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Apply `f` to every element of `inputs` using `workers` threads,
/// returning results in input order. `f` must be `Sync` (it is shared by
/// reference); per-call mutable state should live inside `f`'s locals.
pub fn map_indexed<I, O, F>(inputs: &[I], workers: usize, f: F) -> Vec<O>
where
    I: Sync,
    O: Send,
    F: Fn(usize, &I) -> O + Sync,
{
    let n = inputs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        return inputs.iter().enumerate().map(|(i, x)| f(i, x)).collect();
    }

    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<O>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let f = &f;
    let next = &next;
    let results = &results;

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = f(i, &inputs[i]);
                *results[i].lock().unwrap() = Some(out);
            });
        }
    });

    results
        .iter()
        .map(|m| m.lock().unwrap().take().expect("worker missed a slot"))
        .collect()
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A type-erased borrowing scope job whose capture bytes sit in the
/// worker mailbox's reusable store; `call` moves the capture out of the
/// given pointer and invokes it.
struct RawTask {
    call: unsafe fn(*mut u8),
    size: usize,
    align: usize,
}

/// Reusable raw capture storage: an aligned heap block whose capacity
/// (and alignment) only ever grow, so repeated same-shaped jobs reuse
/// the first allocation — the "pooled job box".
struct RawBuf {
    ptr: *mut u8,
    cap: usize,
    align: usize,
}

// SAFETY: RawBuf is a plain owned allocation; the bytes it holds are
// only ever produced/consumed under the mailbox protocol below.
unsafe impl Send for RawBuf {}

impl RawBuf {
    const fn new() -> RawBuf {
        RawBuf {
            ptr: std::ptr::null_mut(),
            cap: 0,
            align: 1,
        }
    }

    /// Pointer to at least `size` bytes at `align`, reusing the current
    /// allocation when it suffices (the steady-state path allocates
    /// nothing). Zero-sized captures get a well-aligned dangling
    /// pointer.
    fn ensure(&mut self, size: usize, align: usize) -> *mut u8 {
        if size == 0 {
            return align as *mut u8;
        }
        if size <= self.cap && align <= self.align {
            return self.ptr;
        }
        let new_cap = size.max(self.cap);
        let new_align = align.max(self.align);
        self.release();
        let layout = Layout::from_size_align(new_cap, new_align).expect("job capture layout");
        // SAFETY: layout has non-zero size (size > 0 above).
        let ptr = unsafe { std::alloc::alloc(layout) };
        assert!(!ptr.is_null(), "job capture allocation failed");
        self.ptr = ptr;
        self.cap = new_cap;
        self.align = new_align;
        ptr
    }

    fn release(&mut self) {
        if !self.ptr.is_null() {
            let layout =
                Layout::from_size_align(self.cap, self.align).expect("job capture layout");
            // SAFETY: ptr was allocated with exactly this layout.
            unsafe { std::alloc::dealloc(self.ptr, layout) };
            self.ptr = std::ptr::null_mut();
            self.cap = 0;
            self.align = 1;
        }
    }
}

impl Drop for RawBuf {
    fn drop(&mut self) {
        self.release();
    }
}

/// Per-worker mailbox state, guarded by the worker's mutex.
struct WorkerState {
    /// FIFO of fire-and-forget `'static` jobs (`execute`/`execute_on`).
    queue: VecDeque<Job>,
    /// One-deep slot for the pending borrowed scope job (its capture
    /// lives in `store`). Dispatchers wait while it is occupied.
    task: Option<RawTask>,
    /// Pooled capture storage for `task` (capacity persists).
    store: RawBuf,
    /// Set when the worker thread died unwinding a queued job —
    /// dispatch must fail loudly instead of queueing into the void.
    dead: bool,
    /// Set by `Drop`: exit once all queued work is drained.
    shutdown: bool,
}

struct WorkerShared {
    mx: Mutex<WorkerState>,
    cv: Condvar,
}

/// Completion tracking for the (single) active scope.
struct ScopeInner {
    pending: usize,
    /// First panicking job's payload, re-raised by the scope owner.
    payload: Option<Box<dyn Any + Send>>,
}

struct ScopeSync {
    mx: Mutex<ScopeInner>,
    cv: Condvar,
}

struct PoolShared {
    workers: Vec<WorkerShared>,
    scope: ScopeSync,
    /// Guards the one-scope-at-a-time contract (scope state is pooled,
    /// not per-scope, so dispatch stays allocation-free).
    scope_active: AtomicBool,
    /// `true` = a worker whose queued job panics is replaced by a fresh
    /// thread on the same mailbox ([`ThreadPool::respawning`]) instead
    /// of poisoning dispatch. The loud default stays for compute pools,
    /// where a panicking job is a bug the caller must see.
    respawn: bool,
    /// Join handles of respawned replacement threads (the initial
    /// workers' handles live on the [`ThreadPool`] itself).
    extra: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

/// Persistent pool for repeated dispatch without re-spawning threads
/// each generation (or each tick — see the module docs for the pooled
/// scope-dispatch path).
pub struct ThreadPool {
    shared: Arc<PoolShared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    rr: AtomicUsize,
}

impl ThreadPool {
    /// Spawn a pool of `workers` named threads (at least one). A queued
    /// job that panics kills its worker **loudly**: later dispatch to
    /// that worker panics too (the compute pools' contract — a
    /// panicking rollout is a bug, not an operational event).
    pub fn new(workers: usize) -> Self {
        Self::with_respawn(workers, false)
    }

    /// Like [`new`], but a worker whose queued job panics is replaced
    /// by a fresh thread serving the same mailbox — queued and pinned
    /// jobs keep flowing. The serving plane uses this for connection
    /// handlers: one bad handler costs its own connection, never a
    /// session slot for the server's lifetime.
    ///
    /// [`new`]: ThreadPool::new
    pub fn respawning(workers: usize) -> Self {
        Self::with_respawn(workers, true)
    }

    fn with_respawn(workers: usize, respawn: bool) -> Self {
        let workers = workers.max(1);
        let shared = Arc::new(PoolShared {
            workers: (0..workers)
                .map(|_| WorkerShared {
                    mx: Mutex::new(WorkerState {
                        queue: VecDeque::new(),
                        task: None,
                        store: RawBuf::new(),
                        dead: false,
                        shutdown: false,
                    }),
                    cv: Condvar::new(),
                })
                .collect(),
            scope: ScopeSync {
                mx: Mutex::new(ScopeInner {
                    pending: 0,
                    payload: None,
                }),
                cv: Condvar::new(),
            },
            scope_active: AtomicBool::new(false),
            respawn,
            extra: Mutex::new(Vec::new()),
        });
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            handles.push(spawn_worker(Arc::clone(&shared), w).expect("spawn worker"));
        }
        ThreadPool {
            shared,
            handles,
            rr: AtomicUsize::new(0),
        }
    }

    /// Number of worker threads in the pool.
    pub fn workers(&self) -> usize {
        self.shared.workers.len()
    }

    /// Round-robin dispatch of a fire-and-forget job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        let i = self.rr.fetch_add(1, Ordering::Relaxed) % self.workers();
        self.execute_on(i, job);
    }

    /// Dispatch a job to a specific worker (`worker % workers()`).
    ///
    /// Jobs on one worker run sequentially, so pinning gives callers an
    /// exclusivity guarantee: the control server pins each connection
    /// handler to the worker matching its session slot — live slots are
    /// unique, so a long-blocking handler can never queue behind another
    /// live connection. (Pending scope jobs run before queued jobs: the
    /// per-tick dispatch path has latency priority.)
    pub fn execute_on(&self, worker: usize, job: impl FnOnce() + Send + 'static) {
        let ws = &self.shared.workers[worker % self.workers()];
        let mut st = ws.mx.lock().unwrap();
        assert!(!st.dead, "worker hung up (a queued job panicked)");
        st.queue.push_back(Box::new(job));
        drop(st);
        ws.cv.notify_all();
    }

    /// Run borrowing jobs on the pool and **join them all before
    /// returning** — the pool-backed analogue of `std::thread::scope`.
    ///
    /// `f` receives a [`Scope`] handle; jobs spawned through it may
    /// capture non-`'static` references (the caller's locals, disjoint
    /// `&mut` sub-slices, …) because the scope guarantees every job has
    /// finished before `scope` returns — on the normal path *and* when
    /// `f` unwinds. A job that panics is caught on the worker (the
    /// worker thread survives for future dispatch) and its original
    /// panic payload is re-raised from `scope` after all jobs have
    /// drained (first panic wins, like `std::thread::scope`).
    ///
    /// Dispatch through the scope is **allocation-free once warm** (see
    /// the module docs): captures are written into pooled per-worker
    /// job boxes, and the scope's completion state is pool-owned. The
    /// price of pooling that state is that scopes cannot nest or run
    /// concurrently **on the same pool** — doing so panics. (Scopes on
    /// different pools, e.g. a chunked engine whose chunk backends own
    /// their own shard pools, compose freely.)
    ///
    /// The sharded batched stepper and the chunked adaptation engine
    /// use this with [`Scope::spawn_on`] to pin shard/chunk *k* to
    /// worker *k* (dispatch pinned, then join the whole wave).
    pub fn scope<'pool, 'env, R>(&'pool self, f: impl FnOnce(&Scope<'pool, 'env>) -> R) -> R {
        assert!(
            !self.shared.scope_active.swap(true, Ordering::Acquire),
            "ThreadPool::scope does not nest on one pool (scope state is pooled)"
        );
        let scope = Scope {
            pool: self,
            _env: PhantomData,
        };
        // Join even if `f` unwinds: jobs borrow caller state, so they
        // must complete before the caller's frame is torn down.
        struct JoinOnDrop<'a> {
            shared: &'a PoolShared,
            payload: Option<Box<dyn Any + Send>>,
            done: bool,
        }
        impl JoinOnDrop<'_> {
            fn join(&mut self) {
                let mut sc = self.shared.scope.mx.lock().unwrap();
                while sc.pending > 0 {
                    sc = self.shared.scope.cv.wait(sc).unwrap();
                }
                self.payload = sc.payload.take();
                drop(sc);
                self.done = true;
                self.shared.scope_active.store(false, Ordering::Release);
            }
        }
        impl Drop for JoinOnDrop<'_> {
            fn drop(&mut self) {
                if !self.done {
                    // Unwind path: drain the jobs and discard their
                    // panic payload — the caller's panic wins.
                    self.join();
                    self.payload = None;
                }
            }
        }
        let mut guard = JoinOnDrop {
            shared: &self.shared,
            payload: None,
            done: false,
        };
        let result = f(&scope);
        guard.join(); // blocks until every spawned job finished
        if let Some(payload) = guard.payload.take() {
            resume_unwind(payload);
        }
        result
    }

    /// Dispatch a batch of jobs and wait for all to complete, collecting
    /// results in submission order.
    pub fn map<O: Send + 'static>(
        &self,
        jobs: Vec<Box<dyn FnOnce() -> O + Send + 'static>>,
    ) -> Vec<O> {
        let n = jobs.len();
        let results: Arc<Vec<Mutex<Option<O>>>> =
            Arc::new((0..n).map(|_| Mutex::new(None)).collect());
        let done = Arc::new((Mutex::new(0usize), Condvar::new()));
        for (i, job) in jobs.into_iter().enumerate() {
            let results = Arc::clone(&results);
            let done = Arc::clone(&done);
            self.execute(move || {
                let out = job();
                *results[i].lock().unwrap() = Some(out);
                let (lock, cv) = &*done;
                let mut count = lock.lock().unwrap();
                *count += 1;
                cv.notify_one();
            });
        }
        let (lock, cv) = &*done;
        let mut count = lock.lock().unwrap();
        while *count < n {
            count = cv.wait(count).unwrap();
        }
        drop(count);
        // Workers may still hold their Arc clone for an instant after
        // signalling completion, so take results through the mutexes
        // instead of unwrapping the Arc.
        results
            .iter()
            .map(|m| m.lock().unwrap().take().expect("missing result"))
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for ws in &self.shared.workers {
            let mut st = ws.mx.lock().unwrap_or_else(|e| e.into_inner());
            st.shutdown = true;
            drop(st);
            ws.cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        // Respawned replacements too. Once every shutdown flag is set no
        // new respawn passes its check, so the drain loop terminates; a
        // replacement racing the final drain exits on its own when it
        // reads the flag (its handle is merely dropped, not joined).
        loop {
            let extra: Vec<_> = std::mem::take(
                &mut *self.shared.extra.lock().unwrap_or_else(|e| e.into_inner()),
            );
            if extra.is_empty() {
                break;
            }
            for h in extra {
                let _ = h.join();
            }
        }
    }
}

/// Spawn the worker thread serving mailbox `w`.
fn spawn_worker(
    shared: Arc<PoolShared>,
    w: usize,
) -> std::io::Result<std::thread::JoinHandle<()>> {
    std::thread::Builder::new()
        .name(format!("fireflyp-worker-{w}"))
        .spawn(move || {
            let guard = DeadFlag { shared, w };
            worker_loop(&guard.shared, w);
        })
}

/// Unwind guard installed on every worker thread: if a queued `'static`
/// job panics (the only uncaught path — scope jobs are caught on the
/// worker), mark the mailbox dead so later dispatch fails loudly
/// instead of queueing into the void, and release any scope task that
/// was deposited while the thread was already unwinding — it will never
/// run, and leaving its completion slot reserved would hang the scope's
/// join forever. (The orphaned capture's bytes are leaked, not dropped:
/// the worker is already down from a bug, and `RawTask` carries no drop
/// thunk.)
struct DeadFlag {
    shared: Arc<PoolShared>,
    w: usize,
}

impl Drop for DeadFlag {
    fn drop(&mut self) {
        if !std::thread::panicking() {
            return;
        }
        let ws = &self.shared.workers[self.w];
        if self.shared.respawn {
            // Replace the dying thread on the same mailbox: queued jobs
            // (and any scope task deposited during the unwind) are
            // served by the successor, so nothing orphans and `dead`
            // stays false. Skipped once shutdown is underway, and on
            // the (pathological) failure to spawn we fall through to
            // the loud-dead path below.
            let draining = ws.mx.lock().map(|st| st.shutdown).unwrap_or(true);
            if !draining {
                match spawn_worker(Arc::clone(&self.shared), self.w) {
                    Ok(handle) => {
                        crate::log_warn!(
                            "pool worker {} died on a panicking job; respawned",
                            self.w
                        );
                        self.shared
                            .extra
                            .lock()
                            .unwrap_or_else(|e| e.into_inner())
                            .push(handle);
                        return;
                    }
                    Err(e) => {
                        crate::log_warn!("could not respawn pool worker {}: {e}", self.w);
                    }
                }
            } else {
                return;
            }
        }
        let orphan = match ws.mx.lock() {
            Ok(mut st) => {
                st.dead = true;
                st.task.take()
            }
            Err(_) => None,
        };
        ws.cv.notify_all();
        if orphan.is_some() {
            let sync = &self.shared.scope;
            let mut sc = sync.mx.lock().unwrap_or_else(|e| e.into_inner());
            sc.pending -= 1;
            if sc.pending == 0 {
                sync.cv.notify_all();
            }
        }
    }
}

fn worker_loop(shared: &PoolShared, w: usize) {
    let ws = &shared.workers[w];
    // Scratch the scope capture is moved into before invocation, so the
    // mailbox store frees for the next dispatch immediately. Capacity
    // persists — the worker-side half of the pooled job box.
    let mut scratch = RawBuf::new();
    loop {
        let mut st = ws.mx.lock().unwrap();
        loop {
            if st.task.is_some() || !st.queue.is_empty() {
                break;
            }
            if st.shutdown {
                return;
            }
            st = ws.cv.wait(st).unwrap();
        }
        if let Some(task) = st.task.take() {
            // Move the capture bytes out of the mailbox store (a Rust
            // move is a memcpy; the source is dead afterwards), free the
            // slot for the next dispatch, then invoke outside the lock.
            let dst = scratch.ensure(task.size, task.align);
            if task.size > 0 {
                // SAFETY: the dispatcher wrote a live capture of
                // task.size bytes into store; dst has that capacity.
                unsafe { std::ptr::copy_nonoverlapping(st.store.ptr, dst, task.size) };
            }
            drop(st);
            ws.cv.notify_all(); // slot free → a waiting dispatcher may refill
            // SAFETY: dst holds the moved capture; call consumes it.
            let result = catch_unwind(AssertUnwindSafe(|| unsafe { (task.call)(dst) }));
            let mut sc = shared.scope.mx.lock().unwrap();
            if let Err(payload) = result {
                if sc.payload.is_none() {
                    sc.payload = Some(payload);
                }
            }
            sc.pending -= 1;
            if sc.pending == 0 {
                shared.scope.cv.notify_all();
            }
            continue;
        }
        let job = st.queue.pop_front().expect("non-empty queue");
        drop(st);
        job(); // a panic here unwinds the thread; the dead flag fires
    }
}

/// Spawn handle passed to the closure of [`ThreadPool::scope`]. Jobs
/// spawned here may borrow from the enclosing frame (`'env`); the scope
/// joins them all before returning.
pub struct Scope<'pool, 'env> {
    pool: &'pool ThreadPool,
    /// Invariant over `'env`, like `std::thread::Scope`.
    _env: PhantomData<&'env mut &'env ()>,
}

impl<'pool, 'env> Scope<'pool, 'env> {
    /// Spawn a borrowing job on the pool (round-robin worker choice).
    pub fn spawn(&self, job: impl FnOnce() + Send + 'env) {
        let w = self.pool.rr.fetch_add(1, Ordering::Relaxed) % self.pool.workers();
        self.spawn_on(w, job);
    }

    /// Spawn a borrowing job pinned to a specific worker
    /// (`worker % workers()`), preserving [`ThreadPool::execute_on`]'s
    /// exclusivity guarantee: jobs on one worker run sequentially. The
    /// sharded stepper pins shard *k* to worker *k* so consecutive ticks
    /// of a shard reuse the same core's warm cache; the chunked
    /// adaptation engine does the same per scenario chunk.
    ///
    /// The capture is written into the worker's pooled job box — no
    /// per-dispatch boxing. Each worker's mailbox is one job deep: a
    /// second job pinned to a busy worker makes the *dispatcher* wait
    /// until the slot frees (it would have queued behind the first job
    /// anyway). A job must therefore never `spawn_on` its own worker —
    /// that is the same self-deadlock as joining yourself.
    pub fn spawn_on<F: FnOnce() + Send + 'env>(&self, worker: usize, job: F) {
        let shared = &self.pool.shared;
        // Reserve the completion slot before the job can possibly run.
        shared.scope.mx.lock().unwrap().pending += 1;

        let ws = &shared.workers[worker % self.pool.workers()];
        let mut st = ws.mx.lock().unwrap();
        while st.task.is_some() && !st.dead {
            st = ws.cv.wait(st).unwrap();
        }
        if st.dead {
            drop(st);
            // Roll the reservation back so the scope join cannot hang
            // on a job that will never run, then fail loudly.
            let mut sc = shared.scope.mx.lock().unwrap();
            sc.pending -= 1;
            if sc.pending == 0 {
                shared.scope.cv.notify_all();
            }
            drop(sc);
            panic!("worker hung up (a queued job panicked)");
        }

        let size = std::mem::size_of::<F>();
        let align = std::mem::align_of::<F>();
        let ptr = st.store.ensure(size, align);
        // SAFETY: ptr is valid for size bytes at F's alignment; the
        // mailbox protocol guarantees exactly one reader moves the
        // capture back out before the slot is reused. Erasing `F`'s
        // `'env` borrows is sound because the scope joins (blocks until
        // `pending == 0`) before returning — on the success path and,
        // via `JoinOnDrop`, when the scope closure unwinds — the same
        // trick `std::thread::scope`/crossbeam use underneath.
        unsafe { std::ptr::write(ptr.cast::<F>(), job) };
        // SAFETY (caller): p holds a live, moved-in `F`; read consumes
        // it exactly once.
        unsafe fn invoke_erased<F: FnOnce()>(p: *mut u8) {
            (std::ptr::read(p.cast::<F>()))()
        }
        st.task = Some(RawTask {
            call: invoke_erased::<F>,
            size,
            align,
        });
        drop(st);
        ws.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_indexed_preserves_order() {
        let inputs: Vec<u64> = (0..257).collect();
        let out = map_indexed(&inputs, 8, |i, &x| {
            assert_eq!(i as u64, x);
            x * 2
        });
        assert_eq!(out, inputs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn map_indexed_single_worker() {
        let inputs = vec![1, 2, 3];
        let out = map_indexed(&inputs, 1, |_, &x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn map_indexed_empty() {
        let inputs: Vec<u32> = vec![];
        let out: Vec<u32> = map_indexed(&inputs, 4, |_, &x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn pool_map_returns_in_order() {
        let pool = ThreadPool::new(4);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..64usize)
            .map(|i| {
                Box::new(move || {
                    // stagger to exercise out-of-order completion
                    std::thread::sleep(std::time::Duration::from_micros((64 - i) as u64));
                    i
                }) as Box<dyn FnOnce() -> usize + Send>
            })
            .collect();
        let out = pool.map(jobs);
        assert_eq!(out, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn execute_on_pins_to_one_worker() {
        let pool = ThreadPool::new(3);
        let (tx, rx) = std::sync::mpsc::channel::<String>();
        for _ in 0..8 {
            let tx = tx.clone();
            pool.execute_on(1, move || {
                tx.send(std::thread::current().name().unwrap_or("?").to_string())
                    .unwrap();
            });
        }
        drop(tx);
        let names: Vec<String> = rx.iter().collect();
        assert_eq!(names.len(), 8);
        assert!(names.iter().all(|n| n == &names[0]), "jobs spread across workers: {names:?}");
    }

    #[test]
    fn pool_drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool); // must not hang
    }

    #[test]
    fn scope_runs_borrowing_jobs_to_completion() {
        let pool = ThreadPool::new(4);
        let mut data = vec![0u64; 256];
        let (left, right) = data.split_at_mut(128);
        pool.scope(|sc| {
            // disjoint &mut borrows of a caller-owned buffer — the shape
            // the sharded stepper uses
            sc.spawn(|| {
                for (i, v) in left.iter_mut().enumerate() {
                    *v = i as u64;
                }
            });
            sc.spawn(|| {
                for (i, v) in right.iter_mut().enumerate() {
                    *v = 1000 + i as u64;
                }
            });
        });
        // join happened before scope returned: all writes visible
        assert_eq!(data[0], 0);
        assert_eq!(data[127], 127);
        assert_eq!(data[128], 1000);
        assert_eq!(data[255], 1127);
    }

    #[test]
    fn scope_spawn_on_pins_like_execute_on() {
        let pool = ThreadPool::new(3);
        let names = Mutex::new(Vec::new());
        pool.scope(|sc| {
            for _ in 0..6 {
                let names = &names;
                sc.spawn_on(2, move || {
                    names
                        .lock()
                        .unwrap()
                        .push(std::thread::current().name().unwrap_or("?").to_string());
                });
            }
        });
        let names = names.into_inner().unwrap();
        assert_eq!(names.len(), 6);
        assert!(names.iter().all(|n| n == &names[0]), "pinned jobs moved: {names:?}");
    }

    #[test]
    fn scope_is_reusable_and_returns_value() {
        let pool = ThreadPool::new(2);
        for round in 0..5u64 {
            let total = std::sync::atomic::AtomicU64::new(0);
            let got = pool.scope(|sc| {
                for k in 0..8u64 {
                    let total = &total;
                    sc.spawn(move || {
                        total.fetch_add(round * 100 + k, Ordering::SeqCst);
                    });
                }
                "done"
            });
            assert_eq!(got, "done");
            assert_eq!(total.load(Ordering::SeqCst), round * 800 + 28);
        }
    }

    #[test]
    fn scope_propagates_job_panic_but_keeps_workers_alive() {
        let pool = ThreadPool::new(2);
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.scope(|sc| {
                sc.spawn(|| panic!("job boom"));
            });
        }));
        let payload = caught.expect_err("scope must surface the job panic");
        // the original payload is resumed, not a generic wrapper
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .or_else(|| payload.downcast_ref::<String>().map(|s| s.as_str()))
            .unwrap_or("<non-string>");
        assert!(msg.contains("job boom"), "lost panic payload: {msg}");
        // the worker that caught the panic still serves jobs
        let out = pool.map(vec![
            Box::new(|| 1usize) as Box<dyn FnOnce() -> usize + Send>,
            Box::new(|| 2usize),
            Box::new(|| 3usize),
            Box::new(|| 4usize),
        ]);
        assert_eq!(out, vec![1, 2, 3, 4]);
    }

    #[test]
    fn scope_pools_job_boxes_across_capture_shapes() {
        // The pooled mailbox must survive alternating capture sizes and
        // alignments: zero-sized closures, pointer-sized captures, and
        // bulky by-value arrays that force the store to grow.
        static HITS: AtomicUsize = AtomicUsize::new(0);
        let pool = ThreadPool::new(2);
        let mut sum = 0u64;
        let big: [u64; 64] = std::array::from_fn(|i| i as u64);
        for round in 0..20u64 {
            HITS.store(0, Ordering::SeqCst);
            let total = std::sync::atomic::AtomicU64::new(0);
            pool.scope(|sc| {
                // ZST capture
                sc.spawn_on(0, || {
                    HITS.fetch_add(1, Ordering::SeqCst);
                });
                // reference capture (pointer-sized)
                let total_ref = &total;
                sc.spawn_on(1, move || {
                    total_ref.fetch_add(round, Ordering::SeqCst);
                });
                // large by-value capture (moves 512 bytes through the box)
                let arr = big;
                let total_ref = &total;
                sc.spawn_on(0, move || {
                    let s: u64 = arr.iter().sum();
                    total_ref.fetch_add(s, Ordering::SeqCst);
                });
            });
            assert_eq!(HITS.load(Ordering::SeqCst), 1);
            sum += total.load(Ordering::SeqCst);
        }
        // Σ rounds + 20 × Σ 0..64
        assert_eq!(sum, (0..20).sum::<u64>() + 20 * (0..64).sum::<u64>());
    }

    #[test]
    fn nested_scope_on_one_pool_panics() {
        let pool = ThreadPool::new(2);
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.scope(|_outer| {
                pool.scope(|_inner| {});
            });
        }));
        assert!(caught.is_err(), "nesting scopes on one pool must panic");
        // the pool recovers: a fresh scope works
        let flag = AtomicBool::new(false);
        pool.scope(|sc| {
            let flag = &flag;
            sc.spawn(move || flag.store(true, Ordering::SeqCst));
        });
        assert!(flag.load(Ordering::SeqCst));
    }

    #[test]
    fn queued_job_panic_kills_worker_loudly() {
        let pool = ThreadPool::new(2);
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        pool.execute_on(0, move || {
            let _tx = tx; // dropped during unwind → rx disconnects
            panic!("queue boom");
        });
        let _ = rx.recv(); // worker is at least mid-unwind now
        let died = (0..400).any(|_| {
            std::thread::sleep(std::time::Duration::from_millis(5));
            catch_unwind(AssertUnwindSafe(|| pool.execute_on(0, || {}))).is_err()
        });
        assert!(died, "dispatch to a dead worker must fail loudly");
        // the sibling worker is untouched
        let (tx, rx) = std::sync::mpsc::channel::<u32>();
        pool.execute_on(1, move || tx.send(7).unwrap());
        assert_eq!(rx.recv().unwrap(), 7);
    }

    #[test]
    fn respawning_pool_survives_queued_job_panic() {
        let pool = ThreadPool::respawning(2);
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        pool.execute_on(0, move || {
            let _tx = tx; // dropped during unwind → rx disconnects
            panic!("handler boom");
        });
        let _ = rx.recv(); // the worker is at least mid-unwind now
        // Dispatch to the same mailbox keeps working: the replacement
        // thread drains it. (Never panics, unlike the loud default.)
        let (tx, rx) = std::sync::mpsc::channel::<u32>();
        pool.execute_on(0, move || tx.send(41).unwrap());
        assert_eq!(
            rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap(),
            41,
            "respawned worker must serve its mailbox"
        );
        // Jobs queued *behind* a panicking job survive the handoff.
        let (tx, rx) = std::sync::mpsc::channel::<u32>();
        pool.execute_on(1, || panic!("again"));
        pool.execute_on(1, move || tx.send(42).unwrap());
        assert_eq!(
            rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap(),
            42
        );
        drop(pool); // must join replacements without hanging
    }

    #[test]
    fn heavy_parallel_sum() {
        let inputs: Vec<u64> = (0..10_000).collect();
        let out = map_indexed(&inputs, default_workers(), |_, &x| x * x);
        let expect: u64 = inputs.iter().map(|x| x * x).sum();
        assert_eq!(out.iter().sum::<u64>(), expect);
    }
}
