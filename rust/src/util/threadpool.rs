//! Fixed-size thread pool over std channels (the offline registry has no
//! tokio/rayon). Used by the ES leader to fan population rollouts out to
//! worker threads, by the Fig-3 benchmark to run seeds in parallel, and
//! by the sharded batched stepper ([`crate::snn::ShardedNetwork`]) to
//! drive per-shard network steps across cores.
//!
//! Design: a scoped map — `map_indexed` takes a slice of inputs and a
//! worker function and returns outputs in input order. Workers pull
//! indices from a shared atomic counter (work stealing by chunk of 1),
//! which balances heterogeneous rollout lengths well.
//!
//! For repeated dispatch the persistent [`ThreadPool`] additionally
//! offers [`ThreadPool::scope`]: spawn **borrowing** jobs (non-`'static`
//! closures over caller state, e.g. per-shard disjoint `&mut` slices)
//! onto the pool's workers and join them all before the scope returns —
//! the pool-backed analogue of `std::thread::scope`, without re-spawning
//! OS threads every tick.

use std::any::Any;
use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Number of worker threads to use by default: physical parallelism,
/// capped to leave a core for the coordinator.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(4)
}

/// Number of hardware threads available (no coordinator-core reserve) —
/// the default shard count of the batched serving stepper
/// (`--step-threads`).
pub fn available_cores() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Apply `f` to every element of `inputs` using `workers` threads,
/// returning results in input order. `f` must be `Sync` (it is shared by
/// reference); per-call mutable state should live inside `f`'s locals.
pub fn map_indexed<I, O, F>(inputs: &[I], workers: usize, f: F) -> Vec<O>
where
    I: Sync,
    O: Send,
    F: Fn(usize, &I) -> O + Sync,
{
    let n = inputs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        return inputs.iter().enumerate().map(|(i, x)| f(i, x)).collect();
    }

    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<O>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let f = &f;
    let next = &next;
    let results = &results;

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = f(i, &inputs[i]);
                *results[i].lock().unwrap() = Some(out);
            });
        }
    });

    results
        .iter()
        .map(|m| m.lock().unwrap().take().expect("worker missed a slot"))
        .collect()
}

/// Persistent pool for repeated dispatch without re-spawning threads each
/// generation. Jobs are boxed closures; results are retrieved via
/// [`PoolHandle::join`].
pub struct ThreadPool {
    senders: Vec<std::sync::mpsc::Sender<Job>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    rr: AtomicUsize,
}

type Job = Box<dyn FnOnce() + Send + 'static>;

impl ThreadPool {
    /// Spawn a pool of `workers` named threads (at least one).
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let mut senders = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = std::sync::mpsc::channel::<Job>();
            senders.push(tx);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("fireflyp-worker-{w}"))
                    .spawn(move || {
                        while let Ok(job) = rx.recv() {
                            job();
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool {
            senders,
            handles,
            rr: AtomicUsize::new(0),
        }
    }

    /// Number of worker threads in the pool.
    pub fn workers(&self) -> usize {
        self.senders.len()
    }

    /// Round-robin dispatch of a fire-and-forget job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        let i = self.rr.fetch_add(1, Ordering::Relaxed) % self.senders.len();
        self.execute_on(i, job);
    }

    /// Dispatch a job to a specific worker (`worker % workers()`).
    ///
    /// Jobs on one worker run sequentially, so pinning gives callers an
    /// exclusivity guarantee: the control server pins each connection
    /// handler to the worker matching its session slot — live slots are
    /// unique, so a long-blocking handler can never queue behind another
    /// live connection.
    pub fn execute_on(&self, worker: usize, job: impl FnOnce() + Send + 'static) {
        let i = worker % self.senders.len();
        self.senders[i].send(Box::new(job)).expect("worker hung up");
    }

    /// Run borrowing jobs on the pool and **join them all before
    /// returning** — the pool-backed analogue of `std::thread::scope`.
    ///
    /// `f` receives a [`Scope`] handle; jobs spawned through it may
    /// capture non-`'static` references (the caller's locals, disjoint
    /// `&mut` sub-slices, …) because the scope guarantees every job has
    /// finished before `scope` returns — on the normal path *and* when
    /// `f` unwinds. A job that panics is caught on the worker (the
    /// worker thread survives for future dispatch) and its original
    /// panic payload is re-raised from `scope` after all jobs have
    /// drained (first panic wins, like `std::thread::scope`).
    ///
    /// The sharded batched stepper uses this with [`Scope::spawn_on`] to
    /// pin each 64-lane session shard to its own worker
    /// (`join_on`-style: dispatch pinned, then join the whole wave).
    pub fn scope<'pool, 'env, R>(&'pool self, f: impl FnOnce(&Scope<'pool, 'env>) -> R) -> R {
        let scope = Scope {
            pool: self,
            state: Arc::new(ScopeState::default()),
            _env: PhantomData,
        };
        // Join even if `f` unwinds: jobs borrow caller state, so they
        // must complete before the caller's frame is torn down.
        struct JoinOnDrop<'a>(&'a ScopeState);
        impl Drop for JoinOnDrop<'_> {
            fn drop(&mut self) {
                self.0.join();
            }
        }
        let guard = JoinOnDrop(&scope.state);
        let result = f(&scope);
        drop(guard); // blocks until every spawned job finished
        let payload = scope.state.panic_payload.lock().unwrap().take();
        if let Some(payload) = payload {
            resume_unwind(payload);
        }
        result
    }

    /// Dispatch a batch of jobs and wait for all to complete, collecting
    /// results in submission order.
    pub fn map<O: Send + 'static>(
        &self,
        jobs: Vec<Box<dyn FnOnce() -> O + Send + 'static>>,
    ) -> Vec<O> {
        let n = jobs.len();
        let results: Arc<Vec<Mutex<Option<O>>>> =
            Arc::new((0..n).map(|_| Mutex::new(None)).collect());
        let done = Arc::new((Mutex::new(0usize), std::sync::Condvar::new()));
        for (i, job) in jobs.into_iter().enumerate() {
            let results = Arc::clone(&results);
            let done = Arc::clone(&done);
            self.execute(move || {
                let out = job();
                *results[i].lock().unwrap() = Some(out);
                let (lock, cv) = &*done;
                let mut count = lock.lock().unwrap();
                *count += 1;
                cv.notify_one();
            });
        }
        let (lock, cv) = &*done;
        let mut count = lock.lock().unwrap();
        while *count < n {
            count = cv.wait(count).unwrap();
        }
        drop(count);
        // Workers may still hold their Arc clone for an instant after
        // signalling completion, so take results through the mutexes
        // instead of unwrapping the Arc.
        results
            .iter()
            .map(|m| m.lock().unwrap().take().expect("missing result"))
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.senders.clear(); // close channels → workers exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Completion tracking shared between a [`Scope`] and its in-flight jobs.
#[derive(Default)]
struct ScopeState {
    pending: Mutex<usize>,
    done: Condvar,
    /// First panicking job's payload, re-raised by the scope owner.
    panic_payload: Mutex<Option<Box<dyn Any + Send>>>,
}

impl ScopeState {
    fn join(&self) {
        let mut pending = self.pending.lock().unwrap();
        while *pending > 0 {
            pending = self.done.wait(pending).unwrap();
        }
    }
}

/// Spawn handle passed to the closure of [`ThreadPool::scope`]. Jobs
/// spawned here may borrow from the enclosing frame (`'env`); the scope
/// joins them all before returning.
pub struct Scope<'pool, 'env> {
    pool: &'pool ThreadPool,
    state: Arc<ScopeState>,
    /// Invariant over `'env`, like `std::thread::Scope`.
    _env: PhantomData<&'env mut &'env ()>,
}

impl<'pool, 'env> Scope<'pool, 'env> {
    /// Spawn a borrowing job on the pool (round-robin worker choice).
    pub fn spawn(&self, job: impl FnOnce() + Send + 'env) {
        self.dispatch(None, Box::new(job));
    }

    /// Spawn a borrowing job pinned to a specific worker
    /// (`worker % workers()`), preserving [`ThreadPool::execute_on`]'s
    /// exclusivity guarantee: jobs on one worker run sequentially. The
    /// sharded stepper pins shard *k* to worker *k* so consecutive ticks
    /// of a shard reuse the same core's warm cache.
    pub fn spawn_on(&self, worker: usize, job: impl FnOnce() + Send + 'env) {
        self.dispatch(Some(worker), Box::new(job));
    }

    fn dispatch(&self, worker: Option<usize>, job: Box<dyn FnOnce() + Send + 'env>) {
        *self.state.pending.lock().unwrap() += 1;
        let state = Arc::clone(&self.state);
        // SAFETY: the scope joins (blocks on `pending == 0`) before it
        // returns — on the success path and, via `JoinOnDrop`, when the
        // scope closure unwinds — so every borrow captured by `job`
        // outlives the job's execution. Erasing the lifetime is the same
        // trick `std::thread::scope` / crossbeam use underneath.
        let job: Box<dyn FnOnce() + Send + 'static> = unsafe { std::mem::transmute(job) };
        let run = move || {
            if let Err(payload) = catch_unwind(AssertUnwindSafe(job)) {
                let mut slot = state.panic_payload.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(payload);
                }
            }
            let mut pending = state.pending.lock().unwrap();
            *pending -= 1;
            if *pending == 0 {
                state.done.notify_all();
            }
        };
        match worker {
            Some(w) => self.pool.execute_on(w, run),
            None => self.pool.execute(run),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_indexed_preserves_order() {
        let inputs: Vec<u64> = (0..257).collect();
        let out = map_indexed(&inputs, 8, |i, &x| {
            assert_eq!(i as u64, x);
            x * 2
        });
        assert_eq!(out, inputs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn map_indexed_single_worker() {
        let inputs = vec![1, 2, 3];
        let out = map_indexed(&inputs, 1, |_, &x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn map_indexed_empty() {
        let inputs: Vec<u32> = vec![];
        let out: Vec<u32> = map_indexed(&inputs, 4, |_, &x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn pool_map_returns_in_order() {
        let pool = ThreadPool::new(4);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..64usize)
            .map(|i| {
                Box::new(move || {
                    // stagger to exercise out-of-order completion
                    std::thread::sleep(std::time::Duration::from_micros((64 - i) as u64));
                    i
                }) as Box<dyn FnOnce() -> usize + Send>
            })
            .collect();
        let out = pool.map(jobs);
        assert_eq!(out, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn execute_on_pins_to_one_worker() {
        let pool = ThreadPool::new(3);
        let (tx, rx) = std::sync::mpsc::channel::<String>();
        for _ in 0..8 {
            let tx = tx.clone();
            pool.execute_on(1, move || {
                tx.send(std::thread::current().name().unwrap_or("?").to_string())
                    .unwrap();
            });
        }
        drop(tx);
        let names: Vec<String> = rx.iter().collect();
        assert_eq!(names.len(), 8);
        assert!(names.iter().all(|n| n == &names[0]), "jobs spread across workers: {names:?}");
    }

    #[test]
    fn pool_drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool); // must not hang
    }

    #[test]
    fn scope_runs_borrowing_jobs_to_completion() {
        let pool = ThreadPool::new(4);
        let mut data = vec![0u64; 256];
        let (left, right) = data.split_at_mut(128);
        pool.scope(|sc| {
            // disjoint &mut borrows of a caller-owned buffer — the shape
            // the sharded stepper uses
            sc.spawn(|| {
                for (i, v) in left.iter_mut().enumerate() {
                    *v = i as u64;
                }
            });
            sc.spawn(|| {
                for (i, v) in right.iter_mut().enumerate() {
                    *v = 1000 + i as u64;
                }
            });
        });
        // join happened before scope returned: all writes visible
        assert_eq!(data[0], 0);
        assert_eq!(data[127], 127);
        assert_eq!(data[128], 1000);
        assert_eq!(data[255], 1127);
    }

    #[test]
    fn scope_spawn_on_pins_like_execute_on() {
        let pool = ThreadPool::new(3);
        let names = Mutex::new(Vec::new());
        pool.scope(|sc| {
            for _ in 0..6 {
                let names = &names;
                sc.spawn_on(2, move || {
                    names
                        .lock()
                        .unwrap()
                        .push(std::thread::current().name().unwrap_or("?").to_string());
                });
            }
        });
        let names = names.into_inner().unwrap();
        assert_eq!(names.len(), 6);
        assert!(names.iter().all(|n| n == &names[0]), "pinned jobs moved: {names:?}");
    }

    #[test]
    fn scope_is_reusable_and_returns_value() {
        let pool = ThreadPool::new(2);
        for round in 0..5u64 {
            let total = std::sync::atomic::AtomicU64::new(0);
            let got = pool.scope(|sc| {
                for k in 0..8u64 {
                    let total = &total;
                    sc.spawn(move || {
                        total.fetch_add(round * 100 + k, Ordering::SeqCst);
                    });
                }
                "done"
            });
            assert_eq!(got, "done");
            assert_eq!(total.load(Ordering::SeqCst), round * 800 + 28);
        }
    }

    #[test]
    fn scope_propagates_job_panic_but_keeps_workers_alive() {
        let pool = ThreadPool::new(2);
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.scope(|sc| {
                sc.spawn(|| panic!("job boom"));
            });
        }));
        let payload = caught.expect_err("scope must surface the job panic");
        // the original payload is resumed, not a generic wrapper
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .or_else(|| payload.downcast_ref::<String>().map(|s| s.as_str()))
            .unwrap_or("<non-string>");
        assert!(msg.contains("job boom"), "lost panic payload: {msg}");
        // the worker that caught the panic still serves jobs
        let out = pool.map(vec![
            Box::new(|| 1usize) as Box<dyn FnOnce() -> usize + Send>,
            Box::new(|| 2usize),
            Box::new(|| 3usize),
            Box::new(|| 4usize),
        ]);
        assert_eq!(out, vec![1, 2, 3, 4]);
    }

    #[test]
    fn heavy_parallel_sum() {
        let inputs: Vec<u64> = (0..10_000).collect();
        let out = map_indexed(&inputs, default_workers(), |_, &x| x * x);
        let expect: u64 = inputs.iter().map(|x| x * x).sum();
        assert_eq!(out.iter().sum::<u64>(), expect);
    }
}
