//! Fixed-size thread pool over std channels (the offline registry has no
//! tokio/rayon). Used by the ES leader to fan population rollouts out to
//! worker threads and by the Fig-3 benchmark to run seeds in parallel.
//!
//! Design: a scoped map — `map_indexed` takes a slice of inputs and a
//! worker function and returns outputs in input order. Workers pull
//! indices from a shared atomic counter (work stealing by chunk of 1),
//! which balances heterogeneous rollout lengths well.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Number of worker threads to use by default: physical parallelism,
/// capped to leave a core for the coordinator.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(4)
}

/// Apply `f` to every element of `inputs` using `workers` threads,
/// returning results in input order. `f` must be `Sync` (it is shared by
/// reference); per-call mutable state should live inside `f`'s locals.
pub fn map_indexed<I, O, F>(inputs: &[I], workers: usize, f: F) -> Vec<O>
where
    I: Sync,
    O: Send,
    F: Fn(usize, &I) -> O + Sync,
{
    let n = inputs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        return inputs.iter().enumerate().map(|(i, x)| f(i, x)).collect();
    }

    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<O>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let f = &f;
    let next = &next;
    let results = &results;

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = f(i, &inputs[i]);
                *results[i].lock().unwrap() = Some(out);
            });
        }
    });

    results
        .iter()
        .map(|m| m.lock().unwrap().take().expect("worker missed a slot"))
        .collect()
}

/// Persistent pool for repeated dispatch without re-spawning threads each
/// generation. Jobs are boxed closures; results are retrieved via
/// [`PoolHandle::join`].
pub struct ThreadPool {
    senders: Vec<std::sync::mpsc::Sender<Job>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    rr: AtomicUsize,
}

type Job = Box<dyn FnOnce() + Send + 'static>;

impl ThreadPool {
    /// Spawn a pool of `workers` named threads (at least one).
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let mut senders = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = std::sync::mpsc::channel::<Job>();
            senders.push(tx);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("fireflyp-worker-{w}"))
                    .spawn(move || {
                        while let Ok(job) = rx.recv() {
                            job();
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool {
            senders,
            handles,
            rr: AtomicUsize::new(0),
        }
    }

    /// Number of worker threads in the pool.
    pub fn workers(&self) -> usize {
        self.senders.len()
    }

    /// Round-robin dispatch of a fire-and-forget job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        let i = self.rr.fetch_add(1, Ordering::Relaxed) % self.senders.len();
        self.execute_on(i, job);
    }

    /// Dispatch a job to a specific worker (`worker % workers()`).
    ///
    /// Jobs on one worker run sequentially, so pinning gives callers an
    /// exclusivity guarantee: the control server pins each connection
    /// handler to the worker matching its session slot — live slots are
    /// unique, so a long-blocking handler can never queue behind another
    /// live connection.
    pub fn execute_on(&self, worker: usize, job: impl FnOnce() + Send + 'static) {
        let i = worker % self.senders.len();
        self.senders[i].send(Box::new(job)).expect("worker hung up");
    }

    /// Dispatch a batch of jobs and wait for all to complete, collecting
    /// results in submission order.
    pub fn map<O: Send + 'static>(
        &self,
        jobs: Vec<Box<dyn FnOnce() -> O + Send + 'static>>,
    ) -> Vec<O> {
        let n = jobs.len();
        let results: Arc<Vec<Mutex<Option<O>>>> =
            Arc::new((0..n).map(|_| Mutex::new(None)).collect());
        let done = Arc::new((Mutex::new(0usize), std::sync::Condvar::new()));
        for (i, job) in jobs.into_iter().enumerate() {
            let results = Arc::clone(&results);
            let done = Arc::clone(&done);
            self.execute(move || {
                let out = job();
                *results[i].lock().unwrap() = Some(out);
                let (lock, cv) = &*done;
                let mut count = lock.lock().unwrap();
                *count += 1;
                cv.notify_one();
            });
        }
        let (lock, cv) = &*done;
        let mut count = lock.lock().unwrap();
        while *count < n {
            count = cv.wait(count).unwrap();
        }
        drop(count);
        // Workers may still hold their Arc clone for an instant after
        // signalling completion, so take results through the mutexes
        // instead of unwrapping the Arc.
        results
            .iter()
            .map(|m| m.lock().unwrap().take().expect("missing result"))
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.senders.clear(); // close channels → workers exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_indexed_preserves_order() {
        let inputs: Vec<u64> = (0..257).collect();
        let out = map_indexed(&inputs, 8, |i, &x| {
            assert_eq!(i as u64, x);
            x * 2
        });
        assert_eq!(out, inputs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn map_indexed_single_worker() {
        let inputs = vec![1, 2, 3];
        let out = map_indexed(&inputs, 1, |_, &x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn map_indexed_empty() {
        let inputs: Vec<u32> = vec![];
        let out: Vec<u32> = map_indexed(&inputs, 4, |_, &x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn pool_map_returns_in_order() {
        let pool = ThreadPool::new(4);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..64usize)
            .map(|i| {
                Box::new(move || {
                    // stagger to exercise out-of-order completion
                    std::thread::sleep(std::time::Duration::from_micros((64 - i) as u64));
                    i
                }) as Box<dyn FnOnce() -> usize + Send>
            })
            .collect();
        let out = pool.map(jobs);
        assert_eq!(out, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn execute_on_pins_to_one_worker() {
        let pool = ThreadPool::new(3);
        let (tx, rx) = std::sync::mpsc::channel::<String>();
        for _ in 0..8 {
            let tx = tx.clone();
            pool.execute_on(1, move || {
                tx.send(std::thread::current().name().unwrap_or("?").to_string())
                    .unwrap();
            });
        }
        drop(tx);
        let names: Vec<String> = rx.iter().collect();
        assert_eq!(names.len(), 8);
        assert!(names.iter().all(|n| n == &names[0]), "jobs spread across workers: {names:?}");
    }

    #[test]
    fn pool_drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool); // must not hang
    }

    #[test]
    fn heavy_parallel_sum() {
        let inputs: Vec<u64> = (0..10_000).collect();
        let out = map_indexed(&inputs, default_workers(), |_, &x| x * x);
        let expect: u64 = inputs.iter().map(|x| x * x).sum();
        assert_eq!(out.iter().sum::<u64>(), expect);
    }
}
