//! Minimal configuration-file parser (TOML subset; no `serde`/`toml`
//! offline). Supports `[section]` headers, `key = value` pairs with
//! string / number / bool / flat-array values, `#` comments, and typed
//! accessors. Every experiment binary can take `--config path.toml`;
//! CLI options override file values.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

/// One parsed configuration value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// Quoted string value.
    Str(String),
    /// Numeric value (all numbers parse as `f64`).
    Num(f64),
    /// `true` / `false`.
    Bool(bool),
    /// Flat `[a, b, c]` array of values.
    List(Vec<Value>),
}

impl Value {
    /// The string payload, if this is a [`Value::Str`].
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    /// The numeric payload, if this is a [`Value::Num`].
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    /// Numeric payload truncated to `usize`.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    /// The boolean payload, if this is a [`Value::Bool`].
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// The element slice, if this is a [`Value::List`].
    pub fn as_list(&self) -> Option<&[Value]> {
        match self {
            Value::List(v) => Some(v),
            _ => None,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "{s:?}"),
            Value::Num(n) => write!(f, "{n}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::List(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
        }
    }
}

/// Parsed config: `section.key → value`. Keys outside any section live
/// under the empty section `""`.
#[derive(Debug, Default, Clone)]
pub struct Config {
    entries: BTreeMap<String, Value>,
}

/// Parse failure with source location.
#[derive(Debug)]
pub struct ConfigError {
    /// 1-based line number of the offending input line.
    pub line: usize,
    /// Human-readable description of what failed to parse.
    pub message: String,
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ConfigError {}

impl Config {
    /// Parse config text (TOML subset; see the module docs).
    pub fn parse(text: &str) -> Result<Config, ConfigError> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(inner) = line.strip_prefix('[') {
                let name = inner.strip_suffix(']').ok_or(ConfigError {
                    line: lineno + 1,
                    message: "unterminated section header".into(),
                })?;
                section = name.trim().to_string();
                continue;
            }
            let (key, val) = line.split_once('=').ok_or(ConfigError {
                line: lineno + 1,
                message: format!("expected `key = value`, got {line:?}"),
            })?;
            let full_key = if section.is_empty() {
                key.trim().to_string()
            } else {
                format!("{section}.{}", key.trim())
            };
            let value = parse_value(val.trim()).map_err(|message| ConfigError {
                line: lineno + 1,
                message,
            })?;
            cfg.entries.insert(full_key, value);
        }
        Ok(cfg)
    }

    /// Read and parse a config file from disk.
    pub fn load(path: &Path) -> Result<Config, Box<dyn std::error::Error>> {
        let text = std::fs::read_to_string(path)?;
        Ok(Config::parse(&text)?)
    }

    /// Raw value at `section.key` (or bare `key` outside sections).
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    /// String at `key`, falling back to `default`.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    /// Number at `key`, falling back to `default`.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    /// Number at `key` truncated to `usize`, falling back to `default`.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    /// Boolean at `key`, falling back to `default`.
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    /// List at `key` with every numeric element extracted.
    pub fn f64_list(&self, key: &str) -> Option<Vec<f64>> {
        self.get(key)
            .and_then(|v| v.as_list())
            .map(|l| l.iter().filter_map(|x| x.as_f64()).collect())
    }

    /// Merge another config on top of this one (other wins).
    pub fn overlay(&mut self, other: &Config) {
        for (k, v) in &other.entries {
            self.entries.insert(k.clone(), v.clone());
        }
    }

    /// Insert or overwrite one entry programmatically.
    pub fn set(&mut self, key: &str, value: Value) {
        self.entries.insert(key.to_string(), value);
    }

    /// All `section.key` entry names in sorted order.
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // A `#` starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| "unterminated list".to_string())?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in trimmed.split(',') {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(Value::List(items));
    }
    s.parse::<f64>()
        .map(Value::Num)
        .map_err(|_| format!("cannot parse value {s:?} (bare strings must be quoted)"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
seed = 42
name = "fig3-ant"           # inline comment
[es]
population = 256
sigma = 0.1
adaptive = true
[env]
train_dirs = [0, 45, 90, 135, 180, 225, 270, 315]
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.f64_or("seed", 0.0), 42.0);
        assert_eq!(c.str_or("name", ""), "fig3-ant");
        assert_eq!(c.usize_or("es.population", 0), 256);
        assert_eq!(c.f64_or("es.sigma", 0.0), 0.1);
        assert!(c.bool_or("es.adaptive", false));
        assert_eq!(c.f64_list("env.train_dirs").unwrap().len(), 8);
    }

    #[test]
    fn defaults_for_missing_keys() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.usize_or("nope", 7), 7);
        assert_eq!(c.str_or("nope", "x"), "x");
    }

    #[test]
    fn overlay_overrides() {
        let mut base = Config::parse("a = 1\nb = 2").unwrap();
        let top = Config::parse("b = 3").unwrap();
        base.overlay(&top);
        assert_eq!(base.f64_or("a", 0.0), 1.0);
        assert_eq!(base.f64_or("b", 0.0), 3.0);
    }

    #[test]
    fn bad_syntax_reports_line() {
        let err = Config::parse("x = 1\noops").unwrap_err();
        assert_eq!(err.line, 2);
        let err = Config::parse("x = bare").unwrap_err();
        assert!(err.message.contains("quoted"));
    }

    #[test]
    fn hash_inside_string_is_kept() {
        let c = Config::parse(r##"tag = "a#b""##).unwrap();
        assert_eq!(c.str_or("tag", ""), "a#b");
    }

    #[test]
    fn empty_list() {
        let c = Config::parse("xs = []").unwrap();
        assert_eq!(c.f64_list("xs").unwrap().len(), 0);
    }
}
