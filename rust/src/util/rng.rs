//! Deterministic pseudo-random number generation.
//!
//! The offline registry only vendors `rand_core` (no `rand`), so we carry
//! our own PCG64 implementation (O'Neill 2014, PCG-XSL-RR 128/64) plus the
//! sampling helpers the ES and environments need: uniforms, Box–Muller
//! Gaussians, permutations and categorical draws.
//!
//! Every stochastic component in the repository takes an explicit seed and
//! derives per-purpose streams via [`Pcg64::split`], so experiments are
//! reproducible bit-for-bit across runs and thread counts.

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// Second Box–Muller output, cached between `normal()` calls.
    cached_normal: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

/// The complete internal state of a [`Pcg64`], exported for durable
/// snapshots: restoring it reproduces the generator's future output
/// stream bit-for-bit, including a pending cached Box–Muller normal.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PcgState {
    /// 128-bit LCG state word.
    pub state: u128,
    /// Stream increment (odd by construction).
    pub inc: u128,
    /// Second Box–Muller output, if one is pending.
    pub cached_normal: Option<f64>,
}

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Different stream
    /// ids produce statistically independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 64 | 0xda3e_39cb_94b9_5bdb) << 1) | 1;
        let mut rng = Pcg64 {
            state: 0,
            inc,
            cached_normal: None,
        };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.next_u64();
        rng
    }

    /// Export the generator's complete internal state (serving-snapshot
    /// durability). [`Pcg64::restore`] of the result is this generator,
    /// future stream and all.
    pub fn export_state(&self) -> PcgState {
        PcgState {
            state: self.state,
            inc: self.inc,
            cached_normal: self.cached_normal,
        }
    }

    /// Reconstruct a generator from an exported [`PcgState`].
    pub fn restore(s: PcgState) -> Pcg64 {
        Pcg64 {
            state: s.state,
            inc: s.inc,
            cached_normal: s.cached_normal,
        }
    }

    /// Derive an independent child generator (stable function of the
    /// parent's current state) — used to hand per-worker streams to the
    /// ES thread pool without sharing mutable state.
    pub fn split(&mut self) -> Pcg64 {
        let seed = self.next_u64();
        let stream = self.next_u64();
        Pcg64::new(seed, stream)
    }

    /// Next raw 64-bit output of the generator.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Next 32 bits (upper half of a 64-bit draw).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Uses Lemire's multiply-shift with
    /// rejection for exact uniformity.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box–Muller (both outputs used).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = std::f64::consts::TAU * u2;
            self.cached_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// N(mu, sigma^2).
    #[inline]
    pub fn normal_scaled(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Fill a slice with i.i.d. N(0, sigma^2) f32 samples.
    pub fn fill_normal_f32(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = (self.normal() as f32) * sigma;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Poisson(lambda) via Knuth for small lambda (rate coding uses
    /// lambda ≤ a few spikes per step).
    pub fn poisson(&mut self, lambda: f64) -> u32 {
        if lambda <= 0.0 {
            return 0;
        }
        let l = (-lambda).exp();
        let mut k = 0u32;
        let mut p = 1.0;
        loop {
            p *= self.uniform();
            if p <= l {
                return k;
            }
            k += 1;
            if k > 10_000 {
                return k; // guard against lambda abuse
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 0);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 1);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Pcg64::new(7, 0);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_is_uniformish() {
        let mut r = Pcg64::new(1, 2);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(3, 0);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s += z;
            s2 += z * z;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn poisson_mean() {
        let mut r = Pcg64::new(9, 0);
        let lambda = 2.5;
        let n = 50_000;
        let total: u64 = (0..n).map(|_| r.poisson(lambda) as u64).sum();
        let mean = total as f64 / n as f64;
        assert!((mean - lambda).abs() < 0.05, "poisson mean {mean}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Pcg64::new(5, 0);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn export_restore_reproduces_stream_and_cached_normal() {
        let mut a = Pcg64::new(0xBEEF, 3);
        // Leave a Box–Muller second output pending so the export carries
        // it: an odd number of normal() draws caches one.
        let _ = a.normal();
        let mut b = Pcg64::restore(a.export_state());
        assert_eq!(a.normal().to_bits(), b.normal().to_bits());
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut obs_a = vec![0.0f32; 7];
        let mut obs_b = vec![0.0f32; 7];
        a.fill_normal_f32(&mut obs_a, 0.3);
        b.fill_normal_f32(&mut obs_b, 0.3);
        for (x, y) in obs_a.iter().zip(&obs_b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn split_independence() {
        let mut parent = Pcg64::new(11, 0);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        let a: Vec<u64> = (0..8).map(|_| c1.next_u64()).collect();
        let b: Vec<u64> = (0..8).map(|_| c2.next_u64()).collect();
        assert_ne!(a, b);
    }
}
