//! Bit-accurate IEEE 754 binary16 ("half", FP16) arithmetic.
//!
//! FireFly-P performs *all* on-chip arithmetic in FP16 (§III-A: "All
//! computations employ 16-bit floating-point (FP16) arithmetic to balance
//! sensitivity to small weight changes with resource efficiency"). The
//! cycle-accurate FPGA simulator therefore needs a software FP16 that is
//! bit-exact with an IEEE-compliant hardware FPU: correct subnormals,
//! round-to-nearest-even, signed zero, infinities and NaN propagation.
//!
//! We implement binary16 as a `u16` newtype with conversion through f32.
//! The f32→f16 rounding below is the classic round-to-nearest-even
//! truncation of the f32 significand, which matches the behaviour of
//! `vcvtps2ph` / Vivado's `floating_point` IP in RNE mode, so simulator
//! numerics equal what the SpinalHDL design would compute.
//!
//! Arithmetic ops are defined as: convert to f32, compute exactly (every
//! f16×f16 product and f16+f16 sum is exactly representable in f32's
//! 24-bit significand... products always, sums after rounding — see the
//! `exactness` test), round back to f16. For single operations this is
//! equivalent to a native IEEE f16 ALU with RNE.

use std::cmp::Ordering;
use std::fmt;
use std::ops::{Add, AddAssign, Div, Mul, Neg, Sub};

/// IEEE 754 binary16 value, stored as its raw bit pattern.
#[derive(Clone, Copy, Default, PartialEq, Eq)]
pub struct F16(
    /// Raw IEEE 754 binary16 bit pattern (sign·5-bit exp·10-bit frac).
    pub u16,
);

/// Positive zero.
pub const F16_ZERO: F16 = F16(0x0000);
/// Negative zero (compares equal to +0.0 through f32).
pub const F16_NEG_ZERO: F16 = F16(0x8000);
/// 1.0
pub const F16_ONE: F16 = F16(0x3C00);
/// 0.5
pub const F16_HALF: F16 = F16(0x3800);
/// Positive infinity.
pub const F16_INFINITY: F16 = F16(0x7C00);
/// Negative infinity.
pub const F16_NEG_INFINITY: F16 = F16(0xFC00);
/// Canonical quiet NaN.
pub const F16_NAN: F16 = F16(0x7E00);
/// Largest finite f16: 65504.0
pub const F16_MAX: F16 = F16(0x7BFF);
/// Smallest positive normal: 2^-14
pub const F16_MIN_POSITIVE: F16 = F16(0x0400);
/// Smallest positive subnormal: 2^-24
pub const F16_MIN_SUBNORMAL: F16 = F16(0x0001);
/// Machine epsilon for f16: 2^-10
pub const F16_EPSILON: F16 = F16(0x1400);

impl F16 {
    /// Construct from raw bits.
    #[inline]
    pub const fn from_bits(bits: u16) -> Self {
        F16(bits)
    }

    /// Raw bit pattern.
    #[inline]
    pub const fn to_bits(self) -> u16 {
        self.0
    }

    /// Convert an f32 to f16 with round-to-nearest-even.
    ///
    /// Handles overflow→±inf, underflow→subnormals/±0, NaN payload
    /// preservation (quietened).
    pub fn from_f32(x: f32) -> Self {
        let bits = x.to_bits();
        let sign = ((bits >> 16) & 0x8000) as u16;
        let exp = ((bits >> 23) & 0xFF) as i32;
        let frac = bits & 0x7F_FFFF;

        if exp == 0xFF {
            // Inf or NaN.
            return if frac == 0 {
                F16(sign | 0x7C00)
            } else {
                // Quiet NaN, keep top payload bits.
                F16(sign | 0x7C00 | 0x0200 | ((frac >> 13) as u16 & 0x01FF))
            };
        }

        // Unbiased exponent.
        let e = exp - 127;
        if e > 15 {
            // Overflow → infinity (RNE: anything > max rounds to inf).
            return F16(sign | 0x7C00);
        }
        if e >= -14 {
            // Normal range. 23-bit frac → 10-bit with RNE.
            let mut f16_exp = (e + 15) as u16;
            let mut f16_frac = (frac >> 13) as u16;
            let round_bits = frac & 0x1FFF;
            // Round to nearest, ties to even.
            if round_bits > 0x1000 || (round_bits == 0x1000 && (f16_frac & 1) == 1) {
                f16_frac += 1;
                if f16_frac == 0x400 {
                    f16_frac = 0;
                    f16_exp += 1;
                    if f16_exp >= 31 {
                        return F16(sign | 0x7C00);
                    }
                }
            }
            return F16(sign | (f16_exp << 10) | f16_frac);
        }
        // Subnormal or zero. Effective significand = 1.frac * 2^e,
        // to be expressed as 0.xxxx * 2^-14.
        if e < -25 {
            // Rounds to zero even with RNE (< half of min subnormal).
            return F16(sign);
        }
        // Add the implicit leading 1 to get the 24-bit significand, then
        // align to the subnormal grid. Value = sig · 2^(e−23); subnormal
        // frac counts units of 2^-24, so frac10 = sig >> (−1 − e), with
        // e ∈ [−25, −15] ⇒ shift ∈ [14, 24]. RNE on the dropped bits.
        let sig = frac | 0x80_0000;
        let shift_amt = (-1 - e) as u32;
        let mut f16_frac = (sig >> shift_amt) as u16;
        let dropped = sig & ((1u32 << shift_amt) - 1);
        let half = 1u32 << (shift_amt - 1);
        if dropped > half || (dropped == half && (f16_frac & 1) == 1) {
            f16_frac += 1; // may carry into exponent — that's correct (becomes min normal)
        }
        F16(sign | f16_frac)
    }

    /// Convert to f32 (exact — every f16 is representable).
    /// (§Perf note: a 64K-entry LUT was tried here and measured *slower*
    /// — OnceLock check + L2 pressure beat the branchy compute — so the
    /// direct computation stays.)
    #[inline]
    pub fn to_f32(self) -> f32 {
        let sign = ((self.0 & 0x8000) as u32) << 16;
        let exp = ((self.0 >> 10) & 0x1F) as u32;
        let frac = (self.0 & 0x3FF) as u32;
        let bits = if exp == 0 {
            if frac == 0 {
                sign // ±0
            } else {
                // Subnormal: value = 0.frac × 2^-14; normalize to 1.f × 2^e.
                let mut e = -14i32;
                let mut f = frac;
                while f & 0x400 == 0 {
                    f <<= 1;
                    e -= 1;
                }
                f &= 0x3FF;
                sign | (((e + 127) as u32) << 23) | (f << 13)
            }
        } else if exp == 31 {
            if frac == 0 {
                sign | 0x7F80_0000
            } else {
                sign | 0x7FC0_0000 | (frac << 13)
            }
        } else {
            sign | ((exp + 127 - 15) << 23) | (frac << 13)
        };
        f32::from_bits(bits)
    }

    /// True for any NaN bit pattern.
    #[inline]
    pub fn is_nan(self) -> bool {
        (self.0 & 0x7C00) == 0x7C00 && (self.0 & 0x3FF) != 0
    }

    /// True for ±infinity.
    #[inline]
    pub fn is_infinite(self) -> bool {
        (self.0 & 0x7FFF) == 0x7C00
    }

    /// True for every value except ±infinity and NaN.
    #[inline]
    pub fn is_finite(self) -> bool {
        (self.0 & 0x7C00) != 0x7C00
    }

    /// True when the sign bit is set (including −0.0 and negative NaN).
    #[inline]
    pub fn is_sign_negative(self) -> bool {
        self.0 & 0x8000 != 0
    }

    /// True for subnormal values (zero exponent, nonzero fraction).
    #[inline]
    pub fn is_subnormal(self) -> bool {
        (self.0 & 0x7C00) == 0 && (self.0 & 0x3FF) != 0
    }

    /// True for ±0.0.
    #[inline]
    pub fn is_zero(self) -> bool {
        self.0 & 0x7FFF == 0
    }

    /// Absolute value (clears the sign bit; NaN payload preserved).
    #[inline]
    pub fn abs(self) -> Self {
        F16(self.0 & 0x7FFF)
    }

    /// Fused multiply-add rounded once at the end: `self * a + b`.
    ///
    /// The Plasticity Engine's DSP blocks compute `coeff × trace` products
    /// feeding an adder tree; modelling the DSP48E1's internal
    /// higher-precision accumulate is done here via f32 intermediates
    /// (exact for f16 products) with a single terminal rounding.
    pub fn mul_add(self, a: F16, b: F16) -> Self {
        F16::from_f32(self.to_f32() * a.to_f32() + b.to_f32())
    }

    /// Saturating conversion helper: clamp an f32 to the finite f16 range
    /// before rounding. The FPGA datapath saturates instead of producing
    /// infinities on accumulator overflow (standard practice for weight
    /// storage); `snn::plasticity` uses this for weight updates.
    pub fn from_f32_saturating(x: f32) -> Self {
        if x.is_nan() {
            return F16_NAN;
        }
        const MAX: f32 = 65504.0;
        F16::from_f32(x.clamp(-MAX, MAX))
    }

    /// IEEE-style maximum: NaN operands lose to the non-NaN side.
    pub fn max(self, other: F16) -> F16 {
        if self.is_nan() {
            return other;
        }
        if other.is_nan() {
            return self;
        }
        if self.to_f32() >= other.to_f32() {
            self
        } else {
            other
        }
    }

    /// IEEE-style minimum: NaN operands lose to the non-NaN side.
    pub fn min(self, other: F16) -> F16 {
        if self.is_nan() {
            return other;
        }
        if other.is_nan() {
            return self;
        }
        if self.to_f32() <= other.to_f32() {
            self
        } else {
            other
        }
    }
}

impl Add for F16 {
    type Output = F16;
    #[inline]
    fn add(self, rhs: F16) -> F16 {
        F16::from_f32(self.to_f32() + rhs.to_f32())
    }
}

impl AddAssign for F16 {
    #[inline]
    fn add_assign(&mut self, rhs: F16) {
        *self = *self + rhs;
    }
}

impl Sub for F16 {
    type Output = F16;
    #[inline]
    fn sub(self, rhs: F16) -> F16 {
        F16::from_f32(self.to_f32() - rhs.to_f32())
    }
}

impl Mul for F16 {
    type Output = F16;
    #[inline]
    fn mul(self, rhs: F16) -> F16 {
        F16::from_f32(self.to_f32() * rhs.to_f32())
    }
}

impl Div for F16 {
    type Output = F16;
    #[inline]
    fn div(self, rhs: F16) -> F16 {
        F16::from_f32(self.to_f32() / rhs.to_f32())
    }
}

impl Neg for F16 {
    type Output = F16;
    #[inline]
    fn neg(self) -> F16 {
        F16(self.0 ^ 0x8000)
    }
}

impl PartialOrd for F16 {
    fn partial_cmp(&self, other: &F16) -> Option<Ordering> {
        self.to_f32().partial_cmp(&other.to_f32())
    }
}

impl fmt::Debug for F16 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "F16({}={:#06x})", self.to_f32(), self.0)
    }
}

impl fmt::Display for F16 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.to_f32())
    }
}

impl From<f32> for F16 {
    fn from(x: f32) -> F16 {
        F16::from_f32(x)
    }
}

impl From<F16> for f32 {
    fn from(x: F16) -> f32 {
        x.to_f32()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants_round_trip() {
        assert_eq!(F16::from_f32(0.0).to_bits(), 0x0000);
        assert_eq!(F16::from_f32(-0.0).to_bits(), 0x8000);
        assert_eq!(F16::from_f32(1.0).to_bits(), 0x3C00);
        assert_eq!(F16::from_f32(0.5).to_bits(), 0x3800);
        assert_eq!(F16::from_f32(2.0).to_bits(), 0x4000);
        assert_eq!(F16::from_f32(65504.0).to_bits(), 0x7BFF);
        assert_eq!(F16::from_f32(f32::INFINITY).to_bits(), 0x7C00);
        assert_eq!(F16::from_f32(f32::NEG_INFINITY).to_bits(), 0xFC00);
    }

    #[test]
    fn overflow_saturates_to_infinity() {
        assert!(F16::from_f32(65520.0).is_infinite()); // > max, rounds to inf
        assert_eq!(F16::from_f32(65519.0).to_bits(), 0x7BFF); // rounds down to max
        assert!(F16::from_f32(1e10).is_infinite());
        assert_eq!(F16::from_f32_saturating(1e10).to_bits(), 0x7BFF);
        assert_eq!(F16::from_f32_saturating(-1e10).to_bits(), 0xFBFF);
    }

    #[test]
    fn subnormals() {
        // 2^-24 = smallest subnormal
        assert_eq!(F16::from_f32(5.960_464_5e-8).to_bits(), 0x0001);
        assert_eq!(F16(0x0001).to_f32(), 5.960_464_5e-8);
        // 2^-25 is exactly half of min subnormal → ties-to-even → 0
        assert_eq!(F16::from_f32(2.980_232_2e-8).to_bits(), 0x0000);
        // just above 2^-25 rounds up to min subnormal
        assert_eq!(F16::from_f32(2.99e-8).to_bits(), 0x0001);
        // largest subnormal: (1023/1024) * 2^-14
        let largest_sub = 1023.0f32 / 1024.0 * 2f32.powi(-14);
        assert_eq!(F16::from_f32(largest_sub).to_bits(), 0x03FF);
        assert!(F16(0x03FF).is_subnormal());
        // min normal
        assert_eq!(F16::from_f32(2f32.powi(-14)).to_bits(), 0x0400);
    }

    #[test]
    fn subnormal_round_carries_to_normal() {
        // Value just below min-normal should round up into the normal range.
        let just_below = 2f32.powi(-14) - 2f32.powi(-26);
        let h = F16::from_f32(just_below);
        assert_eq!(h.to_bits(), 0x0400); // rounds to min normal
    }

    #[test]
    fn round_to_nearest_even() {
        // 1 + 2^-11 is exactly between 1.0 and 1+2^-10 → ties to even → 1.0
        assert_eq!(F16::from_f32(1.0 + 2f32.powi(-11)).to_bits(), 0x3C00);
        // 1 + 3*2^-11 is between 1+2^-10 and 1+2^-9 → ties to even → 1+2^-9
        assert_eq!(F16::from_f32(1.0 + 3.0 * 2f32.powi(-11)).to_bits(), 0x3C02);
        // 1 + 2^-11 + tiny rounds up
        assert_eq!(F16::from_f32(1.0 + 2f32.powi(-11) + 1e-6).to_bits(), 0x3C01);
    }

    #[test]
    fn nan_propagation() {
        assert!(F16::from_f32(f32::NAN).is_nan());
        assert!((F16_NAN + F16_ONE).is_nan());
        assert!((F16_NAN * F16_ZERO).is_nan());
        assert!((F16_INFINITY - F16_INFINITY).is_nan());
        assert!((F16_ZERO / F16_ZERO).is_nan());
    }

    #[test]
    fn exhaustive_round_trip_f16_f32_f16() {
        // Every one of the 65536 bit patterns must round-trip exactly
        // (NaNs must stay NaN).
        for bits in 0..=u16::MAX {
            let h = F16(bits);
            let back = F16::from_f32(h.to_f32());
            if h.is_nan() {
                assert!(back.is_nan(), "NaN lost at {bits:#06x}");
            } else {
                assert_eq!(back.to_bits(), bits, "round-trip failed at {bits:#06x}");
            }
        }
    }

    #[test]
    fn arithmetic_basics() {
        let a = F16::from_f32(1.5);
        let b = F16::from_f32(2.25);
        assert_eq!((a + b).to_f32(), 3.75);
        assert_eq!((a * b).to_f32(), 3.375);
        assert_eq!((b - a).to_f32(), 0.75);
        assert_eq!((a / F16::from_f32(0.5)).to_f32(), 3.0);
        assert_eq!((-a).to_f32(), -1.5);
    }

    #[test]
    fn fp16_addition_loses_small_addends() {
        // Characteristic FP16 behaviour the plasticity rule must survive:
        // 2048 + 1 == 2048 in f16 (ulp at 2048 is 2).
        let big = F16::from_f32(2048.0);
        let one = F16_ONE;
        assert_eq!((big + one).to_f32(), 2048.0);
        // but 2048 + 2 == 2050
        assert_eq!((big + F16::from_f32(2.0)).to_f32(), 2050.0);
    }

    #[test]
    fn mul_add_single_rounding() {
        // Choose values where (a*b) rounds differently than fma:
        // a*b exact in f32, + c, then single rounding.
        let a = F16::from_f32(1.0 + 2f32.powi(-10)); // 1.0009765625
        let b = a;
        let c = F16::from_f32(-1.0);
        // a*b = 1 + 2^-9 + 2^-20 exactly; fma keeps the 2^-20 tail
        let fused = a.mul_add(b, c);
        let sep = a * b + c;
        // fused: 2^-9 + 2^-20 → rounds (at f16 precision around 2^-9) keeping more info
        assert!(fused.to_f32() >= sep.to_f32());
    }

    #[test]
    fn comparisons() {
        assert!(F16::from_f32(1.0) < F16::from_f32(2.0));
        assert!(F16::from_f32(-1.0) < F16_ZERO);
        assert_eq!(F16_ZERO.max(F16_ONE), F16_ONE);
        assert_eq!(F16_ZERO.min(-F16_ONE), -F16_ONE);
        // ±0 compare equal through f32
        assert_eq!(F16_ZERO.to_f32(), F16_NEG_ZERO.to_f32());
    }

    #[test]
    fn exactness_of_f32_intermediate() {
        // Every f16×f16 product is exact in f32: 11-bit × 11-bit
        // significands → ≤22 bits, f32 has 24. Spot-check the extremes.
        let max = F16_MAX;
        let prod = max * max; // overflows to inf — correct
        assert!(prod.is_infinite());
        let tiny = F16_MIN_SUBNORMAL;
        let p2 = tiny * tiny; // underflows to 0
        assert!(p2.is_zero());
        let m = F16::from_f32(0.000123);
        let n = F16::from_f32(987.0);
        let r = m * n;
        let exact = m.to_f32() * n.to_f32();
        assert_eq!(r.to_bits(), F16::from_f32(exact).to_bits());
    }
}
