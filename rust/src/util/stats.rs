//! Summary statistics and online accumulators used by the benchmark
//! harnesses and the ES fitness bookkeeping.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n−1 denominator; 0.0 for n < 2).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let ss: f64 = xs.iter().map(|x| (x - m) * (x - m)).sum();
    (ss / (xs.len() - 1) as f64).sqrt()
}

/// Standard error of the mean.
pub fn sem(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    std_dev(xs) / (xs.len() as f64).sqrt()
}

/// Percentile via linear interpolation (p in [0, 100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Median (50th percentile, linear interpolation).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Minimum (+inf for empty input).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

/// Maximum (−inf for empty input).
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Centered-rank fitness shaping (maps fitnesses to [−0.5, 0.5]); standard
/// variance-reduction trick for evolution strategies (Salimans et al.).
pub fn centered_ranks(fitness: &[f64]) -> Vec<f64> {
    let n = fitness.len();
    if n <= 1 {
        return vec![0.0; n];
    }
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| fitness[a].partial_cmp(&fitness[b]).unwrap());
    let mut ranks = vec![0.0; n];
    for (rank, &i) in idx.iter().enumerate() {
        ranks[i] = rank as f64 / (n - 1) as f64 - 0.5;
    }
    ranks
}

/// Exponential moving average accumulator.
#[derive(Clone, Debug)]
pub struct Ema {
    /// Smoothing factor in (0, 1]; higher tracks faster.
    pub alpha: f64,
    /// Current estimate (None until the first update).
    pub value: Option<f64>,
}

impl Ema {
    /// Accumulator with smoothing factor `alpha`, initially empty.
    pub fn new(alpha: f64) -> Self {
        Ema { alpha, value: None }
    }
    /// Fold in one sample and return the updated estimate.
    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => prev + self.alpha * (x - prev),
        };
        self.value = Some(v);
        v
    }
    /// Current estimate (0.0 before any update).
    pub fn get(&self) -> f64 {
        self.value.unwrap_or(0.0)
    }
}

/// Welford online mean/variance — used by the metrics registry so the
/// steady-state loop doesn't buffer samples.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    /// Number of samples folded in.
    pub n: u64,
    mean: f64,
    m2: f64,
    /// Smallest sample seen (+inf before any sample).
    pub min: f64,
    /// Largest sample seen (−inf before any sample).
    pub max: f64,
}

impl Welford {
    /// Empty accumulator.
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Fold in one sample.
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Fold another accumulator into this one (Chan et al.'s parallel
    /// combine): the result summarizes the union of both sample sets.
    /// Used by [`crate::coordinator::Metrics::absorb`] to merge
    /// per-chunk registries of a scenario-sharded run in chunk order.
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let na = self.n as f64;
        let nb = other.n as f64;
        let n = na + nb;
        let delta = other.mean - self.mean;
        self.mean += delta * nb / n;
        self.m2 += other.m2 + delta * delta * na * nb / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Running mean (0.0 before any sample).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Sample variance (n−1 denominator; 0.0 for n < 2).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn welford_merge_matches_sequential() {
        let xs: Vec<f64> = (0..50).map(|i| (i as f64) * 0.37 - 3.0).collect();
        for split in [0usize, 1, 25, 49, 50] {
            let mut whole = Welford::new();
            for &x in &xs {
                whole.add(x);
            }
            let (a, b) = xs.split_at(split);
            let mut wa = Welford::new();
            for &x in a {
                wa.add(x);
            }
            let mut wb = Welford::new();
            for &x in b {
                wb.add(x);
            }
            wa.merge(&wb);
            assert_eq!(wa.n, whole.n, "split {split}");
            assert!((wa.mean() - whole.mean()).abs() < 1e-9, "split {split}");
            assert!((wa.variance() - whole.variance()).abs() < 1e-9, "split {split}");
            assert_eq!(wa.min, whole.min, "split {split}");
            assert_eq!(wa.max, whole.max, "split {split}");
        }
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(median(&xs), 3.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
        assert!((percentile(&xs, 10.0) - 1.4).abs() < 1e-12);
    }

    #[test]
    fn ranks_are_centered() {
        let f = [10.0, -1.0, 5.0, 3.0];
        let r = centered_ranks(&f);
        assert_eq!(r.len(), 4);
        let s: f64 = r.iter().sum();
        assert!(s.abs() < 1e-12);
        // best fitness gets +0.5, worst −0.5
        assert_eq!(r[0], 0.5);
        assert_eq!(r[1], -0.5);
    }

    #[test]
    fn welford_matches_batch() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64 * 0.73).sin() * 10.0).collect();
        let mut w = Welford::new();
        for &x in &xs {
            w.add(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-9);
        assert!((w.std_dev() - std_dev(&xs)).abs() < 1e-9);
        assert_eq!(w.min, min(&xs));
        assert_eq!(w.max, max(&xs));
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..64 {
            e.update(10.0);
        }
        assert!((e.get() - 10.0).abs() < 1e-6);
    }

    #[test]
    fn empty_slices_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(centered_ranks(&[]).len(), 0);
    }
}
