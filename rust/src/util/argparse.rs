//! Minimal command-line argument parser (the offline registry has no
//! `clap`). Supports subcommands, `--flag`, `--key value`, `--key=value`,
//! positional arguments, typed accessors with defaults, and auto-generated
//! `--help` text.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Declarative specification of one option.
#[derive(Clone)]
pub struct OptSpec {
    /// Option name as it appears on the command line (without `--`).
    pub name: &'static str,
    /// One-line help text shown by `--help`.
    pub help: &'static str,
    /// Default value seeded before parsing; `None` means absent unless
    /// the user passes the option.
    pub default: Option<&'static str>,
    /// True for presence-only flags (`--quiet`), false for
    /// value-taking options (`--env ant-dir`).
    pub is_flag: bool,
}

/// Parsed arguments for one (sub)command.
#[derive(Default, Debug, Clone)]
pub struct Args {
    /// The subcommand that was invoked, if any.
    pub command: Option<String>,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Non-option arguments in the order they appeared.
    pub positional: Vec<String>,
}

impl Args {
    /// Raw value of `--key`, if present (or seeded by a default).
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// Value of `--key`, falling back to `default`.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Whether the presence-only flag `--key` was passed.
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// `--key` parsed as `usize`; panics with a usage message on a
    /// malformed value, `default` when absent.
    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| {
                v.parse()
                    .unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}"))
            })
            .unwrap_or(default)
    }

    /// `--key` parsed as `u64`; panics on a malformed value, `default`
    /// when absent.
    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| {
                v.parse()
                    .unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}"))
            })
            .unwrap_or(default)
    }

    /// `--key` parsed as `f64`; panics on a malformed value, `default`
    /// when absent.
    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| {
                v.parse()
                    .unwrap_or_else(|_| panic!("--{key} expects a number, got {v:?}"))
            })
            .unwrap_or(default)
    }

    /// `--key` parsed as `f32` (through the f64 path).
    pub fn get_f32(&self, key: &str, default: f32) -> f32 {
        self.get_f64(key, default as f64) as f32
    }
}

/// Parser with subcommand registry.
pub struct Parser {
    /// Program name used in usage/help output.
    pub program: &'static str,
    /// One-line program description for the help header.
    pub about: &'static str,
    commands: Vec<(&'static str, &'static str, Vec<OptSpec>)>,
    global_opts: Vec<OptSpec>,
}

impl Parser {
    /// Empty parser for `program` (add commands/options via the
    /// builder methods).
    pub fn new(program: &'static str, about: &'static str) -> Self {
        Parser {
            program,
            about,
            commands: Vec::new(),
            global_opts: Vec::new(),
        }
    }

    /// Register a value-taking option available to every subcommand.
    pub fn global_opt(mut self, name: &'static str, help: &'static str, default: Option<&'static str>) -> Self {
        self.global_opts.push(OptSpec {
            name,
            help,
            default,
            is_flag: false,
        });
        self
    }

    /// Register a subcommand with its option specs.
    pub fn command(mut self, name: &'static str, help: &'static str, opts: Vec<OptSpec>) -> Self {
        self.commands.push((name, help, opts));
        self
    }

    /// Top-level `--help` text: usage, command list, global options.
    pub fn help_text(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}\n", self.program, self.about);
        let _ = writeln!(s, "USAGE: {} <command> [options]\n", self.program);
        let _ = writeln!(s, "COMMANDS:");
        for (name, help, _) in &self.commands {
            let _ = writeln!(s, "  {name:<18} {help}");
        }
        let _ = writeln!(s, "\nGLOBAL OPTIONS:");
        for o in &self.global_opts {
            let d = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            let _ = writeln!(s, "  --{:<16} {}{}", o.name, o.help, d);
        }
        let _ = writeln!(s, "\nRun `{} <command> --help` for command options.", self.program);
        s
    }

    /// Per-command `--help` text (command options + global options).
    pub fn command_help(&self, cmd: &str) -> String {
        let mut s = String::new();
        if let Some((name, help, opts)) = self.commands.iter().find(|(n, _, _)| *n == cmd) {
            let _ = writeln!(s, "{} {} — {}\n\nOPTIONS:", self.program, name, help);
            for o in opts.iter().chain(self.global_opts.iter()) {
                let d = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
                let kind = if o.is_flag { "(flag)" } else { "" };
                let _ = writeln!(s, "  --{:<16} {} {}{}", o.name, o.help, kind, d);
            }
        }
        s
    }

    /// Parse a raw argv (excluding argv[0]). Returns Err(help_text) when
    /// help was requested or the input is malformed.
    pub fn parse(&self, argv: &[String]) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();

        let cmd = match it.peek() {
            None => return Err(self.help_text()),
            Some(a) if *a == "--help" || *a == "-h" => return Err(self.help_text()),
            Some(a) if a.starts_with('-') => None,
            Some(_) => {
                let c = it.next().unwrap().clone();
                if !self.commands.iter().any(|(n, _, _)| *n == c) {
                    return Err(format!("unknown command {c:?}\n\n{}", self.help_text()));
                }
                Some(c)
            }
        };
        args.command = cmd.clone();

        let specs: Vec<&OptSpec> = self
            .commands
            .iter()
            .find(|(n, _, _)| Some(*n) == cmd.as_deref())
            .map(|(_, _, o)| o.iter().collect::<Vec<_>>())
            .unwrap_or_default()
            .into_iter()
            .chain(self.global_opts.iter())
            .collect();

        // Seed defaults.
        for s in &specs {
            if let Some(d) = s.default {
                args.values.insert(s.name.to_string(), d.to_string());
            }
        }

        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                return Err(match &cmd {
                    Some(c) => self.command_help(c),
                    None => self.help_text(),
                });
            }
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = specs.iter().find(|s| s.name == key);
                let is_flag = spec.map(|s| s.is_flag).unwrap_or_else(|| {
                    // Unknown option: treat as value-taking if followed by
                    // a non-dash token, else as a flag. Lenient by design
                    // so examples can pass through extra options.
                    inline_val.is_none()
                        && !matches!(it.peek(), Some(n) if !n.starts_with('-'))
                });
                if is_flag {
                    args.flags.push(key);
                } else if let Some(v) = inline_val {
                    args.values.insert(key, v);
                } else if let Some(v) = it.next() {
                    args.values.insert(key, v.clone());
                } else {
                    return Err(format!("option --{key} expects a value"));
                }
            } else {
                args.positional.push(a.clone());
            }
        }
        Ok(args)
    }
}

/// Helper to build an OptSpec list tersely.
pub fn opt(name: &'static str, help: &'static str, default: &'static str) -> OptSpec {
    OptSpec {
        name,
        help,
        default: Some(default),
        is_flag: false,
    }
}

/// A required (no-default) value-taking option spec.
pub fn opt_req(name: &'static str, help: &'static str) -> OptSpec {
    OptSpec {
        name,
        help,
        default: None,
        is_flag: false,
    }
}

/// A presence-only flag spec (no value, absent by default).
pub fn flag(name: &'static str, help: &'static str) -> OptSpec {
    OptSpec {
        name,
        help,
        default: None,
        is_flag: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parser() -> Parser {
        Parser::new("firefly-p", "test")
            .global_opt("seed", "rng seed", Some("42"))
            .command(
                "adapt",
                "run online adaptation",
                vec![
                    opt("env", "environment", "ant-dir"),
                    opt("steps", "episode steps", "1000"),
                    flag("fpga", "use the fpga simulator backend"),
                ],
            )
    }

    #[test]
    fn parses_subcommand_and_options() {
        let argv: Vec<String> = ["adapt", "--env", "reacher", "--steps=250", "--fpga"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let a = parser().parse(&argv).unwrap();
        assert_eq!(a.command.as_deref(), Some("adapt"));
        assert_eq!(a.get("env"), Some("reacher"));
        assert_eq!(a.get_usize("steps", 0), 250);
        assert!(a.flag("fpga"));
        assert_eq!(a.get_u64("seed", 0), 42); // global default
    }

    #[test]
    fn defaults_apply() {
        let argv = vec!["adapt".to_string()];
        let a = parser().parse(&argv).unwrap();
        assert_eq!(a.get("env"), Some("ant-dir"));
        assert!(!a.flag("fpga"));
    }

    #[test]
    fn help_is_err() {
        let argv = vec!["--help".to_string()];
        assert!(parser().parse(&argv).is_err());
        let argv = vec!["adapt".to_string(), "--help".to_string()];
        let err = parser().parse(&argv).unwrap_err();
        assert!(err.contains("--env"));
    }

    #[test]
    fn unknown_command_errors() {
        let argv = vec!["bogus".to_string()];
        assert!(parser().parse(&argv).is_err());
    }

    #[test]
    fn positional_args() {
        let argv: Vec<String> = ["adapt", "outfile.csv"].iter().map(|s| s.to_string()).collect();
        let a = parser().parse(&argv).unwrap();
        assert_eq!(a.positional, vec!["outfile.csv"]);
    }
}
