//! CSV writing for experiment outputs. All benchmark harnesses emit their
//! tables/series through this module so EXPERIMENTS.md can point at stable
//! file formats under `results/`.

use std::fmt::Display;
use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

/// A CSV writer with a fixed header checked against every row.
pub struct CsvWriter {
    out: BufWriter<File>,
    /// Destination path the writer was created with.
    pub path: PathBuf,
    columns: usize,
    rows: usize,
}

impl CsvWriter {
    /// Create (and truncate) `path`, writing the header row. Parent
    /// directories are created as needed.
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> std::io::Result<CsvWriter> {
        let path = path.as_ref().to_path_buf();
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        let mut out = BufWriter::new(File::create(&path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter {
            out,
            path,
            columns: header.len(),
            rows: 0,
        })
    }

    /// Write one row; panics if the column count mismatches the header
    /// (a schema bug, not a runtime condition).
    pub fn row(&mut self, fields: &[&dyn Display]) -> std::io::Result<()> {
        assert_eq!(
            fields.len(),
            self.columns,
            "csv row arity mismatch in {}",
            self.path.display()
        );
        let mut line = String::new();
        for (i, f) in fields.iter().enumerate() {
            if i > 0 {
                line.push(',');
            }
            line.push_str(&escape(&f.to_string()));
        }
        writeln!(self.out, "{line}")?;
        self.rows += 1;
        Ok(())
    }

    /// Convenience for all-numeric rows.
    pub fn row_f64(&mut self, fields: &[f64]) -> std::io::Result<()> {
        let refs: Vec<&dyn Display> = fields.iter().map(|f| f as &dyn Display).collect();
        self.row(&refs)
    }

    /// Number of data rows written so far (header excluded).
    pub fn rows_written(&self) -> usize {
        self.rows
    }

    /// Flush buffered output and return the file path.
    pub fn finish(mut self) -> std::io::Result<PathBuf> {
        self.out.flush()?;
        Ok(self.path)
    }
}

fn escape(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Read a CSV produced by [`CsvWriter`] back into (header, rows of
/// strings). Only used by tests and the figure aggregator; handles the
/// quoting `escape` can produce.
pub fn read_csv(path: impl AsRef<Path>) -> std::io::Result<(Vec<String>, Vec<Vec<String>>)> {
    let text = fs::read_to_string(path)?;
    let mut lines = text.lines();
    let header = lines
        .next()
        .map(|h| split_row(h))
        .unwrap_or_default();
    let rows = lines.map(split_row).collect();
    Ok((header, rows))
}

fn split_row(line: &str) -> Vec<String> {
    let mut fields = Vec::new();
    let mut cur = String::new();
    let mut chars = line.chars().peekable();
    let mut in_quotes = false;
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes => {
                if chars.peek() == Some(&'"') {
                    chars.next();
                    cur.push('"');
                } else {
                    in_quotes = false;
                }
            }
            '"' => in_quotes = true,
            ',' if !in_quotes => {
                fields.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    fields.push(cur);
    fields
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_with_quoting() {
        let dir = std::env::temp_dir().join("fireflyp_csv_test");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["name", "value"]).unwrap();
        w.row(&[&"plain", &1.5]).unwrap();
        w.row(&[&"with,comma", &2.0]).unwrap();
        w.row(&[&"with\"quote", &3.0]).unwrap();
        assert_eq!(w.rows_written(), 3);
        w.finish().unwrap();

        let (header, rows) = read_csv(&path).unwrap();
        assert_eq!(header, vec!["name", "value"]);
        assert_eq!(rows[0], vec!["plain", "1.5"]);
        assert_eq!(rows[1], vec!["with,comma", "2"]);
        assert_eq!(rows[2], vec!["with\"quote", "3"]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_mismatch_panics() {
        let dir = std::env::temp_dir().join("fireflyp_csv_test2");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        let _ = w.row(&[&1.0]);
    }
}
