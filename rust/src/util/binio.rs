//! Zero-dependency framed binary codec for durable on-disk state
//! (ISSUE 7 tentpole; DESIGN.md §Durability-and-Faults).
//!
//! The offline registry has no serde/bincode, so durability is built on
//! a small hand-rolled format with exactly the properties crash safety
//! needs:
//!
//! ```text
//! frame := magic("FFPB") version:u16le kind:u16le len:u64le
//!          payload[len] crc32:u32le
//! ```
//!
//! - **Magic + version + kind** make files self-describing: a frame of
//!   the wrong type or from a future format version is a typed error,
//!   never a misparse.
//! - **Length prefix** detects torn writes (a file truncated mid-write
//!   fails the length check before any payload byte is trusted).
//! - **CRC32 trailer** (IEEE 802.3 polynomial, over header + payload)
//!   detects bit rot and partial overwrites.
//!
//! Decoding is *total*: every byte sequence produces `Ok` or a typed
//! [`BinError`] — no panic, no over-allocation from hostile length
//! claims ([`BinReader`] validates every length against the bytes that
//! actually remain). f32/f64 travel as raw bits, so round-trips are
//! bit-exact — the same discipline the wire protocol's shortest
//! round-trip `Display` floats follow.
//!
//! [`write_atomic`] is the durability primitive: tmp file + fsync +
//! rename (+ directory fsync), so a crash leaves either the old file or
//! the new one, never a hybrid.

use std::fmt;
use std::fs::{self, File};
use std::io::{self, Write};
use std::path::{Path, PathBuf};

/// Frame magic: identifies a FireFly-P binary frame.
pub const MAGIC: [u8; 4] = *b"FFPB";

/// Current format version; bump on any layout change.
pub const FORMAT_VERSION: u16 = 1;

const HEADER_LEN: usize = 4 + 2 + 2 + 8;
const TRAILER_LEN: usize = 4;

/// CRC32 lookup table (IEEE 802.3, reflected polynomial 0xEDB88320),
/// built at compile time.
static CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC32 (IEEE 802.3) of `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Typed decode failures. Every variant is a recoverable error — the
/// checkpoint-recovery path quarantines the file and moves on.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BinError {
    /// Fewer bytes than the structure requires (torn write).
    Truncated {
        /// Bytes the decoder needed.
        need: usize,
        /// Bytes actually available.
        have: usize,
    },
    /// The frame does not start with [`MAGIC`].
    BadMagic([u8; 4]),
    /// The frame's format version is not [`FORMAT_VERSION`].
    BadVersion(u16),
    /// The frame holds a different payload kind than requested.
    BadKind {
        /// The kind the caller asked to decode.
        expected: u16,
        /// The kind the frame declares.
        found: u16,
    },
    /// The declared payload length disagrees with the file size.
    BadLength {
        /// Length the header declares.
        declared: u64,
        /// Payload bytes actually present.
        actual: usize,
    },
    /// The CRC32 trailer does not match the frame contents.
    Checksum {
        /// CRC stored in the trailer.
        stored: u32,
        /// CRC computed over the frame.
        computed: u32,
    },
    /// The payload decoded but violates a structural invariant.
    Malformed(String),
}

impl fmt::Display for BinError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BinError::Truncated { need, have } => {
                write!(f, "truncated frame (need {need} bytes, have {have})")
            }
            BinError::BadMagic(m) => write!(f, "bad magic {m:02x?}"),
            BinError::BadVersion(v) => {
                write!(f, "unsupported format version {v} (want {FORMAT_VERSION})")
            }
            BinError::BadKind { expected, found } => {
                write!(f, "wrong frame kind {found:#06x} (want {expected:#06x})")
            }
            BinError::BadLength { declared, actual } => {
                write!(f, "length mismatch (header says {declared}, payload has {actual})")
            }
            BinError::Checksum { stored, computed } => {
                write!(f, "checksum mismatch (stored {stored:#010x}, computed {computed:#010x})")
            }
            BinError::Malformed(msg) => write!(f, "malformed payload: {msg}"),
        }
    }
}

/// Wrap a payload in a checksummed frame of the given `kind`.
pub fn encode_frame(kind: u16, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len() + TRAILER_LEN);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
    out.extend_from_slice(&kind.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(payload);
    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Validate a frame of the given `kind` and return its payload slice.
/// Checks, in order: size, magic, version, kind, declared length (torn
/// writes), CRC32 (bit rot). Never panics.
pub fn decode_frame(bytes: &[u8], kind: u16) -> Result<&[u8], BinError> {
    if bytes.len() < HEADER_LEN + TRAILER_LEN {
        return Err(BinError::Truncated {
            need: HEADER_LEN + TRAILER_LEN,
            have: bytes.len(),
        });
    }
    let magic: [u8; 4] = bytes[0..4].try_into().unwrap();
    if magic != MAGIC {
        return Err(BinError::BadMagic(magic));
    }
    let version = u16::from_le_bytes(bytes[4..6].try_into().unwrap());
    if version != FORMAT_VERSION {
        return Err(BinError::BadVersion(version));
    }
    let found = u16::from_le_bytes(bytes[6..8].try_into().unwrap());
    if found != kind {
        return Err(BinError::BadKind {
            expected: kind,
            found,
        });
    }
    let declared = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
    let actual = bytes.len() - HEADER_LEN - TRAILER_LEN;
    if declared != actual as u64 {
        return Err(BinError::BadLength { declared, actual });
    }
    let body_end = bytes.len() - TRAILER_LEN;
    let stored = u32::from_le_bytes(bytes[body_end..].try_into().unwrap());
    let computed = crc32(&bytes[..body_end]);
    if stored != computed {
        return Err(BinError::Checksum { stored, computed });
    }
    Ok(&bytes[HEADER_LEN..body_end])
}

/// Append-only payload builder with fixed little-endian layouts.
/// Floats are written as raw bits so round-trips are bit-exact.
#[derive(Default)]
pub struct BinWriter {
    buf: Vec<u8>,
}

impl BinWriter {
    /// An empty writer.
    pub fn new() -> BinWriter {
        BinWriter { buf: Vec::new() }
    }

    /// A writer that reuses `buf`'s allocation (cleared first) — the
    /// zero-alloc double-buffering idiom of the serving snapshotter:
    /// once the buffer has grown to steady-state size, re-encoding a
    /// snapshot into it allocates nothing.
    pub fn from_vec(mut buf: Vec<u8>) -> BinWriter {
        buf.clear();
        BinWriter { buf }
    }

    /// The accumulated payload bytes.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Bytes accumulated so far.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when nothing has been written yet.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Open a frame of the given `kind` in place: appends the header
    /// with a zero length placeholder and returns the frame's start
    /// offset for [`BinWriter::seal_frame`]. Frames opened this way can
    /// nest and concatenate inside one buffer without the intermediate
    /// payload `Vec` that [`encode_frame`] costs — this is how the
    /// serving snapshotter stays allocation-free on the stepper thread.
    pub fn begin_frame(&mut self, kind: u16) -> usize {
        let start = self.buf.len();
        self.buf.extend_from_slice(&MAGIC);
        self.buf.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        self.buf.extend_from_slice(&kind.to_le_bytes());
        self.buf.extend_from_slice(&0u64.to_le_bytes());
        start
    }

    /// Close a frame opened by [`BinWriter::begin_frame`]: patches the
    /// declared length and appends the CRC32 trailer over everything
    /// written since `start`. The resulting bytes are exactly what
    /// [`encode_frame`] would have produced.
    pub fn seal_frame(&mut self, start: usize) {
        let payload_len = (self.buf.len() - start - HEADER_LEN) as u64;
        self.buf[start + 8..start + 16].copy_from_slice(&payload_len.to_le_bytes());
        let crc = crc32(&self.buf[start..]);
        self.buf.extend_from_slice(&crc.to_le_bytes());
    }

    /// Append one byte.
    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Append a `bool` as one byte (0/1).
    pub fn put_bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    /// Append a `u32` (little-endian).
    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u64` (little-endian).
    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `usize` as `u64`.
    pub fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    /// Append an `Option<usize>` as a presence byte + `u64`.
    pub fn put_opt_usize(&mut self, v: Option<usize>) {
        match v {
            Some(n) => {
                self.put_u8(1);
                self.put_usize(n);
            }
            None => self.put_u8(0),
        }
    }

    /// Append an `f32` as its raw bits (bit-exact round-trip).
    pub fn put_f32(&mut self, v: f32) {
        self.put_u32(v.to_bits());
    }

    /// Append an `f64` as its raw bits (bit-exact round-trip).
    pub fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    /// Append a length-prefixed UTF-8 string.
    pub fn put_str(&mut self, s: &str) {
        self.put_usize(s.len());
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Append a length-prefixed `f32` slice (raw bits each).
    pub fn put_f32s(&mut self, xs: &[f32]) {
        self.put_usize(xs.len());
        for &x in xs {
            self.put_f32(x);
        }
    }

    /// Append a length-prefixed `f64` slice (raw bits each).
    pub fn put_f64s(&mut self, xs: &[f64]) {
        self.put_usize(xs.len());
        for &x in xs {
            self.put_f64(x);
        }
    }

    /// Append a length-prefixed `u32` slice (little-endian each) — the
    /// scalar bit-pattern lanes of serving snapshots.
    pub fn put_u32s(&mut self, xs: &[u32]) {
        self.put_usize(xs.len());
        for &x in xs {
            self.put_u32(x);
        }
    }

    /// Append a length-prefixed `u64` slice (little-endian each) —
    /// packed spike words and lazy-decay clocks.
    pub fn put_u64s(&mut self, xs: &[u64]) {
        self.put_usize(xs.len());
        for &x in xs {
            self.put_u64(x);
        }
    }
}

/// Cursor over a payload slice; the mirror of [`BinWriter`]. Every read
/// is bounds-checked and every length claim is validated against the
/// bytes that remain, so hostile input cannot panic the decoder or bait
/// it into a huge allocation.
pub struct BinReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> BinReader<'a> {
    /// A reader over `payload` (typically from [`decode_frame`]).
    pub fn new(payload: &'a [u8]) -> BinReader<'a> {
        BinReader { buf: payload, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], BinError> {
        if self.remaining() < n {
            return Err(BinError::Truncated {
                need: n,
                have: self.remaining(),
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Read one byte.
    pub fn get_u8(&mut self) -> Result<u8, BinError> {
        Ok(self.take(1)?[0])
    }

    /// Read a `bool` (rejecting bytes other than 0/1).
    pub fn get_bool(&mut self) -> Result<bool, BinError> {
        match self.get_u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => Err(BinError::Malformed(format!("bad bool byte {other}"))),
        }
    }

    /// Read a `u32` (little-endian).
    pub fn get_u32(&mut self) -> Result<u32, BinError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// Read a `u64` (little-endian).
    pub fn get_u64(&mut self) -> Result<u64, BinError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Read a `usize` (written as `u64`), rejecting values that cannot
    /// fit in the platform's `usize`.
    pub fn get_usize(&mut self) -> Result<usize, BinError> {
        let v = self.get_u64()?;
        usize::try_from(v).map_err(|_| BinError::Malformed(format!("usize overflow: {v}")))
    }

    /// Read an `Option<usize>` (presence byte + `u64`).
    pub fn get_opt_usize(&mut self) -> Result<Option<usize>, BinError> {
        match self.get_u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.get_usize()?)),
            other => Err(BinError::Malformed(format!("bad option tag {other}"))),
        }
    }

    /// Read an `f32` from raw bits.
    pub fn get_f32(&mut self) -> Result<f32, BinError> {
        Ok(f32::from_bits(self.get_u32()?))
    }

    /// Read an `f64` from raw bits.
    pub fn get_f64(&mut self) -> Result<f64, BinError> {
        Ok(f64::from_bits(self.get_u64()?))
    }

    /// Read a length prefix that claims `elem_size`-byte elements,
    /// rejecting claims larger than the bytes that remain (so a corrupt
    /// length cannot drive a huge `Vec` pre-allocation).
    pub fn get_len(&mut self, elem_size: usize) -> Result<usize, BinError> {
        let n = self.get_usize()?;
        let need = n.checked_mul(elem_size.max(1)).ok_or_else(|| {
            BinError::Malformed(format!("length overflow: {n} x {elem_size}"))
        })?;
        if need > self.remaining() {
            return Err(BinError::Truncated {
                need,
                have: self.remaining(),
            });
        }
        Ok(n)
    }

    /// Read a length-prefixed UTF-8 string.
    pub fn get_str(&mut self) -> Result<String, BinError> {
        let n = self.get_len(1)?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|e| BinError::Malformed(format!("invalid utf-8 string: {e}")))
    }

    /// Read a length-prefixed `f32` vector.
    pub fn get_f32s(&mut self) -> Result<Vec<f32>, BinError> {
        let n = self.get_len(4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.get_f32()?);
        }
        Ok(out)
    }

    /// Read a length-prefixed `f64` vector.
    pub fn get_f64s(&mut self) -> Result<Vec<f64>, BinError> {
        let n = self.get_len(8)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.get_f64()?);
        }
        Ok(out)
    }

    /// Read a length-prefixed `u32` vector.
    pub fn get_u32s(&mut self) -> Result<Vec<u32>, BinError> {
        let n = self.get_len(4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.get_u32()?);
        }
        Ok(out)
    }

    /// Read a length-prefixed `u64` vector.
    pub fn get_u64s(&mut self) -> Result<Vec<u64>, BinError> {
        let n = self.get_len(8)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.get_u64()?);
        }
        Ok(out)
    }

    /// Decode a nested frame of the given `kind` starting at the cursor
    /// and return a reader over its payload, advancing past the frame.
    /// The declared length is validated against the remaining bytes
    /// before anything is trusted, then the full [`decode_frame`]
    /// battery (magic, version, kind, length, CRC32) runs on the slice —
    /// a torn or corrupt nested frame is a typed error, never a panic.
    pub fn get_frame(&mut self, kind: u16) -> Result<BinReader<'a>, BinError> {
        if self.remaining() < HEADER_LEN + TRAILER_LEN {
            return Err(BinError::Truncated {
                need: HEADER_LEN + TRAILER_LEN,
                have: self.remaining(),
            });
        }
        let declared =
            u64::from_le_bytes(self.buf[self.pos + 8..self.pos + 16].try_into().unwrap());
        let total = usize::try_from(declared)
            .ok()
            .and_then(|p| p.checked_add(HEADER_LEN + TRAILER_LEN))
            .ok_or_else(|| {
                BinError::Malformed(format!("nested frame length overflow: {declared}"))
            })?;
        if total > self.remaining() {
            return Err(BinError::Truncated {
                need: total,
                have: self.remaining(),
            });
        }
        let bytes = self.take(total)?;
        Ok(BinReader::new(decode_frame(bytes, kind)?))
    }

    /// Assert the payload is fully consumed (trailing garbage inside a
    /// valid checksum is still a malformed payload).
    pub fn finish(&self) -> Result<(), BinError> {
        if self.remaining() != 0 {
            return Err(BinError::Malformed(format!(
                "{} trailing payload bytes",
                self.remaining()
            )));
        }
        Ok(())
    }
}

/// The path [`write_atomic`] stages its temporary file at.
fn tmp_path(path: &Path) -> PathBuf {
    let mut name = path.file_name().unwrap_or_default().to_os_string();
    name.push(".tmp");
    path.with_file_name(name)
}

/// Durably replace `path` with `bytes`: write a sibling tmp file, fsync
/// it, rename over `path`, then fsync the directory. A crash at any
/// point leaves either the old complete file or the new complete file —
/// the frame checksum catches whatever a pathological filesystem leaves
/// anyway.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> io::Result<()> {
    let tmp = tmp_path(path);
    {
        let mut f = File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    fs::rename(&tmp, path)?;
    if let Some(dir) = path.parent() {
        if let Ok(d) = File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn crc32_matches_reference_vector() {
        // The canonical IEEE 802.3 check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn primitives_round_trip_bit_exactly() {
        check(200, |g| {
            let u8v = g.usize_range(0, 256) as u8;
            let u32v = g.u64() as u32;
            let u64v = g.u64();
            let f32v = f32::from_bits(g.u64() as u32);
            let f64v = f64::from_bits(g.u64());
            let opt = if g.bool() { Some(g.usize_range(0, 1 << 40)) } else { None };
            let s: String = (0..g.usize_range(0, 20))
                .map(|_| char::from_u32(g.usize_range(32, 0x2FF) as u32).unwrap_or('x'))
                .collect();
            let f32s: Vec<f32> = (0..g.usize_range(0, 16))
                .map(|_| f32::from_bits(g.u64() as u32))
                .collect();
            let f64s: Vec<f64> = (0..g.usize_range(0, 16))
                .map(|_| f64::from_bits(g.u64()))
                .collect();

            let mut w = BinWriter::new();
            w.put_u8(u8v);
            w.put_bool(true);
            w.put_u32(u32v);
            w.put_u64(u64v);
            w.put_f32(f32v);
            w.put_f64(f64v);
            w.put_opt_usize(opt);
            w.put_str(&s);
            w.put_f32s(&f32s);
            w.put_f64s(&f64s);
            let bytes = w.into_bytes();

            let mut r = BinReader::new(&bytes);
            assert_eq!(r.get_u8().unwrap(), u8v);
            assert!(r.get_bool().unwrap());
            assert_eq!(r.get_u32().unwrap(), u32v);
            assert_eq!(r.get_u64().unwrap(), u64v);
            assert_eq!(r.get_f32().unwrap().to_bits(), f32v.to_bits());
            assert_eq!(r.get_f64().unwrap().to_bits(), f64v.to_bits());
            assert_eq!(r.get_opt_usize().unwrap(), opt);
            assert_eq!(r.get_str().unwrap(), s);
            let rf32 = r.get_f32s().unwrap();
            let rf64 = r.get_f64s().unwrap();
            assert_eq!(
                rf32.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                f32s.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
            assert_eq!(
                rf64.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                f64s.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
            r.finish().unwrap();
        });
    }

    #[test]
    fn frame_round_trips_and_validates() {
        let frame = encode_frame(7, b"hello world");
        assert_eq!(decode_frame(&frame, 7).unwrap(), b"hello world");
        // Wrong kind is typed.
        assert!(matches!(
            decode_frame(&frame, 8),
            Err(BinError::BadKind { expected: 8, found: 7 })
        ));
        // Empty payloads are legal.
        let empty = encode_frame(0, b"");
        assert_eq!(decode_frame(&empty, 0).unwrap(), b"");
    }

    #[test]
    fn truncation_at_every_length_is_a_typed_error() {
        let frame = encode_frame(3, b"payload bytes here");
        for cut in 0..frame.len() {
            let err = decode_frame(&frame[..cut], 3)
                .expect_err("truncated frame must not decode");
            assert!(
                matches!(
                    err,
                    BinError::Truncated { .. } | BinError::BadLength { .. }
                ),
                "cut at {cut}: unexpected {err:?}"
            );
        }
    }

    #[test]
    fn every_single_bit_flip_is_detected() {
        let frame = encode_frame(1, b"checksummed");
        for byte in 0..frame.len() {
            for bit in 0..8u8 {
                let mut bad = frame.clone();
                bad[byte] ^= 1 << bit;
                assert!(
                    decode_frame(&bad, 1).is_err(),
                    "flip at byte {byte} bit {bit} must not decode"
                );
            }
        }
    }

    #[test]
    fn random_garbage_never_panics_or_decodes() {
        check(500, |g| {
            let n = g.usize_range(0, 256);
            let bytes: Vec<u8> = (0..n).map(|_| g.u64() as u8).collect();
            // (a 2^-32 false-accept would need magic+version+kind+len
            // all consistent as well — treat any Ok as a test failure)
            assert!(decode_frame(&bytes, 42).is_err());
        });
    }

    #[test]
    fn hostile_length_claims_cannot_force_allocation() {
        let mut w = BinWriter::new();
        w.put_usize(usize::MAX / 2); // claims ~2^63 f64 elements
        let bytes = w.into_bytes();
        let mut r = BinReader::new(&bytes);
        assert!(r.get_f64s().is_err());
        let mut r = BinReader::new(&bytes);
        assert!(r.get_str().is_err());
    }

    #[test]
    fn in_place_frames_match_encode_frame_and_nest() {
        // begin/seal produces the exact bytes of encode_frame.
        let mut w = BinWriter::new();
        let start = w.begin_frame(9);
        w.put_str("payload");
        w.put_u64s(&[1, u64::MAX, 42]);
        w.seal_frame(start);
        let mut payload = BinWriter::new();
        payload.put_str("payload");
        payload.put_u64s(&[1, u64::MAX, 42]);
        assert_eq!(w.into_bytes(), encode_frame(9, &payload.into_bytes()));

        // Nested frames: an outer frame carrying two inner frames plus
        // scalar fields, decoded through get_frame.
        let mut w = BinWriter::from_vec(Vec::with_capacity(64));
        let outer = w.begin_frame(1);
        w.put_u64(7);
        let inner_a = w.begin_frame(2);
        w.put_u32s(&[0xDEAD_BEEF, 0]);
        w.seal_frame(inner_a);
        let inner_b = w.begin_frame(3);
        w.put_bool(true);
        w.seal_frame(inner_b);
        w.seal_frame(outer);
        let bytes = w.into_bytes();

        let payload = decode_frame(&bytes, 1).unwrap();
        let mut r = BinReader::new(payload);
        assert_eq!(r.get_u64().unwrap(), 7);
        let mut a = r.get_frame(2).unwrap();
        assert_eq!(a.get_u32s().unwrap(), vec![0xDEAD_BEEF, 0]);
        a.finish().unwrap();
        let mut b = r.get_frame(3).unwrap();
        assert!(b.get_bool().unwrap());
        b.finish().unwrap();
        r.finish().unwrap();

        // Wrong nested kind and flipped nested bytes are typed errors.
        let mut r = BinReader::new(payload);
        let _ = r.get_u64().unwrap();
        assert!(matches!(
            r.get_frame(5),
            Err(BinError::BadKind { expected: 5, found: 2 })
        ));
        let mut bad = bytes.clone();
        let flip = HEADER_LEN + 8 + HEADER_LEN + 2; // inside inner frame a
        bad[flip] ^= 0x10;
        // The outer CRC covers everything, so the outer decode already
        // rejects; a caller that skipped it still gets a typed nested
        // error, never a panic.
        assert!(decode_frame(&bad, 1).is_err());

        // from_vec reuses capacity without reallocating.
        let recycled = BinWriter::from_vec(bytes);
        assert!(recycled.is_empty());
    }

    #[test]
    fn truncated_nested_frame_is_typed() {
        let mut w = BinWriter::new();
        let inner = w.begin_frame(4);
        w.put_f32s(&[1.0, 2.0]);
        w.seal_frame(inner);
        let bytes = w.into_bytes();
        for cut in 0..bytes.len() {
            let mut r = BinReader::new(&bytes[..cut]);
            assert!(r.get_frame(4).is_err(), "cut at {cut} must not decode");
        }
        let mut r = BinReader::new(&bytes);
        let mut f = r.get_frame(4).unwrap();
        assert_eq!(f.get_f32s().unwrap(), vec![1.0, 2.0]);
    }

    #[test]
    fn u32s_u64s_round_trip() {
        check(100, |g| {
            let u32s: Vec<u32> = (0..g.usize_range(0, 24)).map(|_| g.u64() as u32).collect();
            let u64s: Vec<u64> = (0..g.usize_range(0, 24)).map(|_| g.u64()).collect();
            let mut w = BinWriter::new();
            w.put_u32s(&u32s);
            w.put_u64s(&u64s);
            let bytes = w.into_bytes();
            let mut r = BinReader::new(&bytes);
            assert_eq!(r.get_u32s().unwrap(), u32s);
            assert_eq!(r.get_u64s().unwrap(), u64s);
            r.finish().unwrap();
        });
    }

    #[test]
    fn write_atomic_replaces_file_and_cleans_tmp() {
        let dir = std::env::temp_dir().join(format!("binio-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("frame.bin");
        write_atomic(&path, &encode_frame(1, b"one")).unwrap();
        assert_eq!(decode_frame(&std::fs::read(&path).unwrap(), 1).unwrap(), b"one");
        write_atomic(&path, &encode_frame(1, b"two")).unwrap();
        assert_eq!(decode_frame(&std::fs::read(&path).unwrap(), 1).unwrap(), b"two");
        assert!(!tmp_path(&path).exists(), "tmp staging file must not linger");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
