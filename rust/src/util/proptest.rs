//! Tiny property-based testing driver (no `proptest` crate offline).
//!
//! A property is a closure over a [`Gen`] that panics on violation. The
//! runner executes it for `cases` seeds; on failure it reports the seed so
//! the case can be replayed deterministically:
//!
//! ```ignore
//! check(100, |g| {
//!     let w = g.f32_range(-4.0, 4.0);
//!     assert!(quantize(w).to_f32().abs() <= 4.0);
//! });
//! ```

use crate::util::rng::Pcg64;

/// Per-case generator handed to properties.
pub struct Gen {
    /// The case's deterministic random stream (usable directly for
    /// draws the helpers below don't cover).
    pub rng: Pcg64,
    /// The case's seed — embed it in assertion messages so failures
    /// replay via [`check_seeded`].
    pub seed: u64,
}

impl Gen {
    /// A uniform random `u64`.
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// A uniform `usize` in `[lo, hi)`.
    pub fn usize_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.rng.below((hi - lo) as u64) as usize
    }

    /// A uniform `f64` in `[lo, hi)`.
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform_range(lo, hi)
    }

    /// A uniform `f32` in `[lo, hi)`.
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform_range(lo as f64, hi as f64) as f32
    }

    /// A fair coin flip.
    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }

    /// A centered Gaussian draw with standard deviation `sigma`.
    pub fn normal_f32(&mut self, sigma: f32) -> f32 {
        (self.rng.normal() as f32) * sigma
    }

    /// A vector of f32s drawn uniformly from [lo, hi).
    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_range(lo, hi)).collect()
    }

    /// An "interesting" f32: mixes ordinary magnitudes with edge values
    /// (0, ±tiny, ±huge, exact powers of two) to probe FP16 rounding.
    pub fn edgy_f32(&mut self) -> f32 {
        match self.rng.below(8) {
            0 => 0.0,
            1 => -0.0,
            2 => self.f32_range(-6e-8, 6e-8),      // subnormal f16 range
            3 => self.f32_range(-70000.0, 70000.0), // overflow boundary
            4 => 2f32.powi(self.usize_range(0, 30) as i32 - 15),
            5 => -(2f32.powi(self.usize_range(0, 30) as i32 - 15)),
            _ => self.f32_range(-100.0, 100.0),
        }
    }
}

/// Run `prop` for `cases` deterministic seeds (derived from a fixed master
/// seed, so CI is stable). Panics with the failing seed embedded.
pub fn check(cases: u64, prop: impl Fn(&mut Gen)) {
    check_seeded(0xF1EE_F1Ee, cases, prop);
}

/// As [`check`] with an explicit master seed (use to replay a failure).
pub fn check_seeded(master: u64, cases: u64, prop: impl Fn(&mut Gen)) {
    for case in 0..cases {
        let seed = master.wrapping_add(case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut g = Gen {
            rng: Pcg64::new(seed, case),
            seed,
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!("property failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0u64;
        check(50, |_g| {
            // cannot capture &mut through Fn; use a cell
        });
        // Count via a cell-based variant:
        let counter = std::sync::atomic::AtomicU64::new(0);
        check(50, |_g| {
            counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        count += counter.load(std::sync::atomic::Ordering::Relaxed);
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_seed() {
        check(20, |g| {
            let x = g.f64_range(0.0, 1.0);
            assert!(x < 0.5, "x too big: {x}");
        });
    }

    #[test]
    fn deterministic_replay() {
        let mut first: Vec<u64> = Vec::new();
        let collected = std::sync::Mutex::new(Vec::new());
        check(10, |g| {
            collected.lock().unwrap().push(g.u64());
        });
        first.extend(collected.lock().unwrap().iter());
        let collected2 = std::sync::Mutex::new(Vec::new());
        check(10, |g| {
            collected2.lock().unwrap().push(g.u64());
        });
        assert_eq!(first, *collected2.lock().unwrap());
    }
}
