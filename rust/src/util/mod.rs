//! Foundation utilities built from scratch (the offline crate registry
//! vendors only the `xla` crate's dependency closure, so there is no
//! clap/serde/rand/half/criterion/proptest — each is replaced by a small
//! purpose-built module here).

pub mod argparse;
pub mod binio;
pub mod config;
pub mod csvio;
pub mod faults;
pub mod fixed;
pub mod fp16;
pub mod logging;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod threadpool;
