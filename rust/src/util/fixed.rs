//! Integer Q-format fixed-point arithmetic — the hardware-parity scalar
//! domain (ROADMAP "fixed-point quantized batched backend").
//!
//! FireFly-P's published datapath computes in FP16, but the packed-integer
//! engineering that FireFly (arXiv:2301.01905) and FireFly v2
//! (arXiv:2309.16158) earn their throughput from is narrow *fixed-point*:
//! 16-bit lanes double effective SIMD width over f32 and halve the
//! working set, and a DSP slice's multiply-accumulate is an integer
//! operation with one requantization at the end. [`Qfx`] is that
//! arithmetic as a software scalar: an `i16` Q5.10 value (1 sign bit,
//! 5 integer bits, [`Qfx::FRAC`] = 10 fraction bits) on which **every
//! operation rounds like a DSP ALU** —
//!
//! - add/sub **saturate** to the representable range ([`Qfx::MIN`],
//!   [`Qfx::MAX`]) instead of wrapping or overflowing to ±inf,
//! - multiply computes the exact double-width integer product and
//!   **requantizes once with round-to-nearest-even** (RNE) back to the
//!   Q5.10 grid, saturating on overflow,
//! - [`Qfx::mul_add`] keeps the product in the wide accumulator, aligns
//!   the addend, and performs a **single terminal RNE requantization** —
//!   the DSP48-style fused multiply-accumulate,
//! - [`Qfx::from_f32`] quantizes with RNE and saturates; there is no NaN
//!   or infinity in the format (`NaN` quantizes to zero — see
//!   [`Qfx::from_f32`]).
//!
//! The Q5.10 split is chosen by the network's value ranges: the paper
//! constants λ = 0.5, v_th = 1.0, w_clip = 4.0 and input gain 2.0 are all
//! exactly representable, the λ = 0.5 trace saturation 1/(1−λ) = 2 sits
//! well inside the ±32 span, and the 2⁻¹⁰ quantum resolves the default
//! η = 0.05 learning-rate scale to 51 quanta. Deeper fraction widths
//! trade psum headroom for weight resolution; the width is a single
//! constant ([`Qfx::FRAC`]) so a different Q-format is one edit plus a
//! conformance re-run.
//!
//! Mirroring the FP16 contract in [`crate::snn::numeric`]: exactly one
//! rounding per operation, so the simulator lane and the batched backend
//! agree bit-for-bit by construction (`tests/fixed_point_conformance.rs`).
//! λ = 0.5 decay is RNE halving of the raw value — every value decays to
//! exactly 0 in at most 16 steps, giving the lazy-trace machinery its
//! decay fixed point, and a drained lane is *exactly* zero (the cold
//! invariant the plasticity gate's hot-mask prefilter relies on).

use std::cmp::Ordering;
use std::fmt;
use std::ops::{Add, AddAssign, Mul, Neg, Sub};

/// Q5.10 fixed-point value, stored as its raw scaled integer: the
/// represented value is `raw / 2^FRAC`.
#[derive(Clone, Copy, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Qfx(
    /// Raw two's-complement payload (value × 2¹⁰).
    pub i16,
);

impl Qfx {
    /// Fraction width of the Q-format (Q5.10: 1 sign + 5 integer +
    /// `FRAC` fraction bits).
    pub const FRAC: u32 = 10;
    /// Scale factor `2^FRAC` relating raw payloads to values.
    pub const SCALE: i32 = 1 << Self::FRAC;
    /// Additive identity.
    pub const ZERO: Qfx = Qfx(0);
    /// Multiplicative identity (raw `2^FRAC`).
    pub const ONE: Qfx = Qfx(1 << Self::FRAC);
    /// One half — the λ = 0.5 decay constant (raw `2^(FRAC−1)`).
    pub const HALF: Qfx = Qfx(1 << (Self::FRAC - 1));
    /// Largest representable value: `(2^15 − 1) / 2^10` ≈ 31.999.
    pub const MAX: Qfx = Qfx(i16::MAX);
    /// Most negative representable value: `−2^15 / 2^10` = −32.
    pub const MIN: Qfx = Qfx(i16::MIN);
    /// One quantum, `2^−FRAC` — the resolution of the grid.
    pub const EPSILON: Qfx = Qfx(1);

    /// Construct from a raw scaled payload.
    #[inline]
    pub const fn from_bits(bits: i16) -> Self {
        Qfx(bits)
    }

    /// Raw scaled payload.
    #[inline]
    pub const fn to_bits(self) -> i16 {
        self.0
    }

    /// Quantize an f32 onto the Q5.10 grid with round-to-nearest-even,
    /// saturating to [`Qfx::MIN`]/[`Qfx::MAX`] (±inf included — the
    /// format has no infinities). `NaN` quantizes to [`Qfx::ZERO`]: a
    /// fixed-point datapath has no non-numeric encoding, so the
    /// non-finite contract ([`crate::snn::numeric::Scalar::saturating_add`])
    /// maps NaN to the additive identity in every domain.
    pub fn from_f32(x: f32) -> Self {
        if x.is_nan() {
            return Qfx::ZERO;
        }
        // ×2^FRAC is exact in f64 for every finite f32 (pure exponent
        // shift), so the RNE below is the only rounding performed.
        let scaled = (x as f64) * Self::SCALE as f64;
        if scaled >= i16::MAX as f64 {
            return Qfx::MAX;
        }
        if scaled <= i16::MIN as f64 {
            return Qfx::MIN;
        }
        let floor = scaled.floor();
        let rem = scaled - floor;
        let mut n = floor as i32;
        if rem > 0.5 || (rem == 0.5 && (n & 1) == 1) {
            n += 1;
        }
        Qfx(sat16(n))
    }

    /// Widen to f32 — exact: every Q5.10 value is an integer multiple of
    /// 2⁻¹⁰ with ≤ 15 significant bits.
    #[inline]
    pub fn to_f32(self) -> f32 {
        self.0 as f32 / Self::SCALE as f32
    }

    /// Saturating addition (the DSP adder never wraps).
    #[inline]
    pub fn sat_add(self, rhs: Qfx) -> Qfx {
        Qfx(sat16(self.0 as i32 + rhs.0 as i32))
    }

    /// Saturating subtraction.
    #[inline]
    pub fn sat_sub(self, rhs: Qfx) -> Qfx {
        Qfx(sat16(self.0 as i32 - rhs.0 as i32))
    }

    /// Multiply: exact 32-bit product, one RNE requantization back to the
    /// Q5.10 grid, saturate on overflow.
    #[inline]
    pub fn sat_mul(self, rhs: Qfx) -> Qfx {
        Qfx(sat16(rne_shr(self.0 as i32 * rhs.0 as i32, Self::FRAC)))
    }

    /// Fused multiply-add `self·a + b`, DSP-style: the double-width
    /// product stays in the wide accumulator, `b` is aligned up to the
    /// product's fraction width, and a **single** terminal RNE
    /// requantization (then saturation) produces the result — matching
    /// the one-rounding profile of [`crate::util::fp16::F16::mul_add`].
    #[inline]
    pub fn mul_add(self, a: Qfx, b: Qfx) -> Qfx {
        let wide = self.0 as i32 * a.0 as i32 + ((b.0 as i32) << Self::FRAC);
        Qfx(sat16(rne_shr(wide, Self::FRAC)))
    }

    /// Absolute value (saturating: `|MIN|` clamps to [`Qfx::MAX`]).
    #[inline]
    pub fn abs(self) -> Qfx {
        Qfx(sat16((self.0 as i32).abs()))
    }

    /// True for every `Qfx` — the format has no NaN or infinities.
    #[inline]
    pub fn is_finite(self) -> bool {
        true
    }
}

/// Saturate a 32-bit intermediate to the i16 payload range.
#[inline]
fn sat16(x: i32) -> i16 {
    x.clamp(i16::MIN as i32, i16::MAX as i32) as i16
}

/// Arithmetic shift right by `shift` with round-to-nearest-even on the
/// dropped bits — the requantization step of every multiply. Works for
/// negative values too: `>>` on `i32` floors, leaving a non-negative
/// remainder to round.
#[inline]
fn rne_shr(x: i32, shift: u32) -> i32 {
    let floor = x >> shift;
    let rem = x - (floor << shift);
    let half = 1i32 << (shift - 1);
    if rem > half || (rem == half && (floor & 1) == 1) {
        floor + 1
    } else {
        floor
    }
}

impl Add for Qfx {
    type Output = Qfx;
    #[inline]
    fn add(self, rhs: Qfx) -> Qfx {
        self.sat_add(rhs)
    }
}

impl AddAssign for Qfx {
    #[inline]
    fn add_assign(&mut self, rhs: Qfx) {
        *self = self.sat_add(rhs);
    }
}

impl Sub for Qfx {
    type Output = Qfx;
    #[inline]
    fn sub(self, rhs: Qfx) -> Qfx {
        self.sat_sub(rhs)
    }
}

impl Mul for Qfx {
    type Output = Qfx;
    #[inline]
    fn mul(self, rhs: Qfx) -> Qfx {
        self.sat_mul(rhs)
    }
}

impl Neg for Qfx {
    type Output = Qfx;
    #[inline]
    fn neg(self) -> Qfx {
        Qfx(sat16(-(self.0 as i32)))
    }
}

impl fmt::Debug for Qfx {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Qfx({}={:#06x})", self.to_f32(), self.0 as u16)
    }
}

impl fmt::Display for Qfx {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.to_f32())
    }
}

impl From<f32> for Qfx {
    fn from(x: f32) -> Qfx {
        Qfx::from_f32(x)
    }
}

impl From<Qfx> for f32 {
    fn from(x: Qfx) -> f32 {
        x.to_f32()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants_are_exact() {
        assert_eq!(Qfx::from_f32(0.0).to_bits(), 0);
        assert_eq!(Qfx::from_f32(1.0), Qfx::ONE);
        assert_eq!(Qfx::from_f32(0.5), Qfx::HALF);
        assert_eq!(Qfx::from_f32(4.0).to_bits(), 4 << Qfx::FRAC);
        assert_eq!(Qfx::from_f32(2.0).to_bits(), 2 << Qfx::FRAC);
        assert_eq!(Qfx::from_f32(-4.0).to_bits(), -(4 << Qfx::FRAC) as i16);
    }

    #[test]
    fn exhaustive_round_trip_qfx_f32_qfx() {
        // Every raw payload must survive the f32 round trip exactly
        // (to_f32 is exact, from_f32 rounds a grid point to itself).
        for bits in i16::MIN..=i16::MAX {
            let q = Qfx(bits);
            assert_eq!(Qfx::from_f32(q.to_f32()).to_bits(), bits, "round-trip failed at {bits}");
        }
    }

    #[test]
    fn from_f32_rounds_to_nearest_even() {
        let quantum = 1.0 / Qfx::SCALE as f32;
        // exact midpoint between raw 0 and raw 1 → ties to even → 0
        assert_eq!(Qfx::from_f32(quantum * 0.5).to_bits(), 0);
        // midpoint between raw 1 and raw 2 → ties to even → 2
        assert_eq!(Qfx::from_f32(quantum * 1.5).to_bits(), 2);
        // just above a midpoint rounds up
        assert_eq!(Qfx::from_f32(quantum * 0.5 + 1e-6).to_bits(), 1);
        // negative midpoints tie to even as well
        assert_eq!(Qfx::from_f32(-quantum * 0.5).to_bits(), 0);
        assert_eq!(Qfx::from_f32(-quantum * 1.5).to_bits(), -2);
    }

    #[test]
    fn from_f32_saturates_nonfinite_and_out_of_range() {
        assert_eq!(Qfx::from_f32(1e9), Qfx::MAX);
        assert_eq!(Qfx::from_f32(-1e9), Qfx::MIN);
        assert_eq!(Qfx::from_f32(f32::INFINITY), Qfx::MAX);
        assert_eq!(Qfx::from_f32(f32::NEG_INFINITY), Qfx::MIN);
        assert_eq!(Qfx::from_f32(f32::NAN), Qfx::ZERO);
        // value just past the positive edge rounds into saturation
        assert_eq!(Qfx::from_f32(32.0), Qfx::MAX);
    }

    #[test]
    fn add_sub_saturate() {
        assert_eq!(Qfx::MAX + Qfx::ONE, Qfx::MAX);
        assert_eq!(Qfx::MIN - Qfx::ONE, Qfx::MIN);
        assert_eq!(Qfx::MAX + Qfx::MAX, Qfx::MAX);
        assert_eq!((Qfx::from_f32(1.5) + Qfx::from_f32(2.25)).to_f32(), 3.75);
        assert_eq!((Qfx::from_f32(2.25) - Qfx::from_f32(1.5)).to_f32(), 0.75);
        assert_eq!(-Qfx::MIN, Qfx::MAX, "negating MIN saturates");
    }

    #[test]
    fn mul_requantizes_with_rne() {
        // 1.5 × 2.25 = 3.375: exactly on the grid, no rounding.
        assert_eq!((Qfx::from_f32(1.5) * Qfx::from_f32(2.25)).to_f32(), 3.375);
        // quantum × 0.5 = half a quantum → ties to even → 0
        assert_eq!((Qfx::EPSILON * Qfx::HALF).to_bits(), 0);
        // 3 quanta × 0.5 = 1.5 quanta → ties to even → 2
        assert_eq!((Qfx(3) * Qfx::HALF).to_bits(), 2);
        // overflow saturates instead of wrapping
        assert_eq!(Qfx::from_f32(8.0) * Qfx::from_f32(8.0), Qfx::MAX);
        assert_eq!(Qfx::from_f32(-8.0) * Qfx::from_f32(8.0), Qfx::MIN);
    }

    #[test]
    fn rne_shr_floors_negatives_correctly() {
        // −1 quantum halved: −0.5 quanta → ties to even → 0
        assert_eq!((Qfx(-1) * Qfx::HALF).to_bits(), 0);
        // −3 quanta halved: −1.5 → ties to even → −2
        assert_eq!((Qfx(-3) * Qfx::HALF).to_bits(), -2);
        // −2 quanta halved: exact −1
        assert_eq!((Qfx(-2) * Qfx::HALF).to_bits(), -1);
    }

    #[test]
    fn every_value_decays_to_exactly_zero() {
        // λ = 0.5 decay must reach the 0 fixed point for every starting
        // value — the lazy-trace cold invariant (a drained lane is
        // *exactly* zero) and the decay-horizon bound.
        for start in [Qfx::MAX, Qfx::ONE, Qfx(3), Qfx::EPSILON, Qfx(-7), Qfx::MIN] {
            let mut v = start;
            let mut steps = 0;
            while v != Qfx::ZERO {
                let nv = v * Qfx::HALF;
                assert_ne!(nv, v, "stuck at {v:?} (non-zero fixed point)");
                v = nv;
                steps += 1;
                assert!(steps <= 16, "decay horizon exceeded from {start:?}");
            }
        }
    }

    #[test]
    fn mul_add_single_terminal_rounding() {
        // Choose operands where the separate mul would round away a
        // half-quantum that the fused path keeps: 1.5 quanta product.
        let a = Qfx(3);
        let b = Qfx::HALF;
        let c = Qfx(5);
        // wide product = 3·512 = 1536 = 1.5 quanta; + c aligned (5120)
        // → 6656 → RNE(>>10) = 6.5 → ties to even → 6
        assert_eq!(a.mul_add(b, c).to_bits(), 6);
        // separate ops: (3·0.5 → RNE → 2) + 5 = 7 — one extra rounding
        assert_eq!((a * b + c).to_bits(), 7);
    }

    #[test]
    fn ordering_matches_values() {
        assert!(Qfx::from_f32(-1.0) < Qfx::ZERO);
        assert!(Qfx::ZERO < Qfx::EPSILON);
        assert!(Qfx::from_f32(1.0) < Qfx::from_f32(2.0));
        assert_eq!(Qfx::from_f32(0.25).partial_cmp(&Qfx::from_f32(0.25)), Some(Ordering::Equal));
    }

    #[test]
    fn abs_saturates_min() {
        assert_eq!(Qfx(-5).abs(), Qfx(5));
        assert_eq!(Qfx::MIN.abs(), Qfx::MAX);
    }
}
