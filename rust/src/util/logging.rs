//! Lightweight leveled logging to stderr with monotonic timestamps.
//! Level is controlled by `FIREFLY_LOG` (error|warn|info|debug|trace) or
//! programmatically via [`set_level`]; default is `info`.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log severity, ordered from most to least severe.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
#[repr(u8)]
pub enum Level {
    /// Unrecoverable failures.
    Error = 0,
    /// Suspicious-but-survivable conditions.
    Warn = 1,
    /// Operational progress (the default level).
    Info = 2,
    /// Diagnostic detail for debugging sessions.
    Debug = 3,
    /// Very chatty per-step tracing.
    Trace = 4,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    fn from_str(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized
static START: OnceLock<Instant> = OnceLock::new();

fn current_level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l != 255 {
        return l;
    }
    let from_env = std::env::var("FIREFLY_LOG")
        .ok()
        .and_then(|s| Level::from_str(&s))
        .unwrap_or(Level::Info) as u8;
    LEVEL.store(from_env, Ordering::Relaxed);
    from_env
}

/// Override the log level programmatically (wins over `FIREFLY_LOG`).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Whether a message at `level` would currently be emitted.
pub fn enabled(level: Level) -> bool {
    (level as u8) <= current_level()
}

/// Emit one log line (used through the `log_*!` macros, which supply
/// the module path).
pub fn log(level: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let start = START.get_or_init(Instant::now);
    let t = start.elapsed().as_secs_f64();
    eprintln!("[{t:10.4}s {} {module}] {msg}", level.tag());
}

/// Log at [`Level::Error`] with `format!` syntax.
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*)) };
}
/// Log at [`Level::Warn`] with `format!` syntax.
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*)) };
}
/// Log at [`Level::Info`] with `format!` syntax.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*)) };
}
/// Log at [`Level::Debug`] with `format!` syntax.
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*)) };
}
/// Log at [`Level::Trace`] with `format!` syntax.
#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Trace, module_path!(), format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }

    #[test]
    fn parse_level() {
        assert_eq!(Level::from_str("debug"), Some(Level::Debug));
        assert_eq!(Level::from_str("WARNING"), Some(Level::Warn));
        assert_eq!(Level::from_str("nope"), None);
    }
}
