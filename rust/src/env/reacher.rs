//! Reacher — 2-link planar arm reaching to random goal positions (the
//! Brax *ur5e* reaching task, §IV-A, reduced to its planar essence).
//!
//! Model: two revolute joints with torque control, viscous joint damping
//! and a light coupling between the links (the inertial simplification
//! keeps the dynamics honest — torque on the shoulder accelerates the
//! elbow — without a full manipulator-equation solve). Link lengths sum
//! to the `GOAL_RADIUS` used by the task protocol, so every goal is
//! reachable.
//!
//! Reward per step = −‖tip − goal‖ − control cost, plus a proximity bonus
//! inside 5 cm that rewards *settling* on the goal rather than orbiting.

use super::perturb::Perturbation;
use super::protocol::{TaskFamily, TaskParam, GOAL_RADIUS};
use super::Env;
use crate::util::rng::Pcg64;

const DT: f32 = 0.05;
const L1: f32 = 0.45;
const L2: f32 = 0.35;
const DAMPING: f32 = 1.8;
const TORQUE_GAIN: f32 = 4.0;
/// Acceleration coupling from shoulder to elbow (and reaction back).
const COUPLING: f32 = 0.3;
const CTRL_COST: f32 = 0.02;
const BONUS_RADIUS: f32 = 0.05;
const HORIZON: usize = 150;

/// 2-link planar arm reaching to random goal positions (see the module
/// docs for the dynamics model).
pub struct Reacher {
    q: [f32; 2],
    dq: [f32; 2],
    goal: (f32, f32),
    t: usize,
    perturbation: Option<Perturbation>,
}

impl Reacher {
    /// Arm at the straight home pose with a default goal on the +x axis.
    pub fn new() -> Self {
        Reacher {
            q: [0.0; 2],
            dq: [0.0; 2],
            goal: (0.5, 0.0),
            t: 0,
            perturbation: None,
        }
    }

    /// World-frame position of the arm's tip (forward kinematics).
    pub fn tip(&self) -> (f32, f32) {
        let x = L1 * self.q[0].cos() + L2 * (self.q[0] + self.q[1]).cos();
        let y = L1 * self.q[0].sin() + L2 * (self.q[0] + self.q[1]).sin();
        (x, y)
    }

    /// Euclidean distance from the tip to the commanded goal.
    pub fn distance_to_goal(&self) -> f32 {
        let (tx, ty) = self.tip();
        ((tx - self.goal.0).powi(2) + (ty - self.goal.1).powi(2)).sqrt()
    }

    /// Write the current observation into `out` (cleared first) — the
    /// allocation-free primitive both [`Env::step_into`] and the
    /// allocating wrappers share, so their values are identical.
    fn observation_into(&self, out: &mut Vec<f32>) {
        let (tx, ty) = self.tip();
        out.clear();
        out.extend_from_slice(&[
            self.q[0].cos(),
            self.q[0].sin(),
            self.q[1].cos(),
            self.q[1].sin(),
            self.dq[0],
            self.dq[1],
            self.goal.0,
            self.goal.1,
            self.goal.0 - tx,
            self.goal.1 - ty,
        ]);
        if let Some(p) = &self.perturbation {
            p.filter_obs(out);
        }
    }

    fn observation(&self) -> Vec<f32> {
        let mut obs = Vec::with_capacity(10);
        self.observation_into(&mut obs);
        obs
    }
}

impl Default for Reacher {
    fn default() -> Self {
        Self::new()
    }
}

impl Env for Reacher {
    fn obs_dim(&self) -> usize {
        10
    }

    fn act_dim(&self) -> usize {
        2
    }

    fn reset(&mut self, task: &TaskParam, rng: &mut Pcg64) -> Vec<f32> {
        assert_eq!(task.family, TaskFamily::Position, "Reacher needs a position task");
        // Arm starts with the elbow bent (q₂ ≈ 1.2 rad) plus jitter — a
        // straight arm is a Jacobian singularity from which torque control
        // converges badly (true for the real ur5e task too, whose home
        // pose is articulated).
        self.q = [
            rng.uniform_range(-0.1, 0.1) as f32,
            1.2 + rng.uniform_range(-0.1, 0.1) as f32,
        ];
        self.dq = [0.0; 2];
        // Scale protocol goals (radius ≤ GOAL_RADIUS) into reach: L1+L2
        // equals GOAL_RADIUS exactly, so use them directly.
        debug_assert!((L1 + L2 - GOAL_RADIUS as f32).abs() < 1e-6);
        self.goal = (task.value as f32, task.value2 as f32);
        self.t = 0;
        self.perturbation = None;
        self.observation()
    }

    fn step_into(&mut self, action: &[f32], obs_out: &mut Vec<f32>) -> (f32, bool) {
        assert_eq!(action.len(), 2);
        let mut a = [action[0].clamp(-1.0, 1.0), action[1].clamp(-1.0, 1.0)];
        if let Some(p) = &self.perturbation {
            // Filter the stack buffer in place — no per-step heap
            // allocation (the old path round-tripped through a Vec).
            p.filter_action(&mut a);
        }

        // Coupled double-integrator joint dynamics with damping.
        let tau0 = TORQUE_GAIN * a[0] - DAMPING * self.dq[0] - COUPLING * self.dq[1];
        let tau1 = TORQUE_GAIN * a[1] - DAMPING * self.dq[1] - COUPLING * self.dq[0];
        // External force acts on the tip; project onto joint torques via
        // a crude Jacobian-transpose (sufficient for the wind scenario).
        let (mut j0, mut j1) = (0.0f32, 0.0f32);
        if let Some(p) = &self.perturbation {
            let (fx, fy) = p.external_force();
            if fx != 0.0 || fy != 0.0 {
                let s01 = (self.q[0] + self.q[1]).sin();
                let c01 = (self.q[0] + self.q[1]).cos();
                let jx0 = -L1 * self.q[0].sin() - L2 * s01;
                let jy0 = L1 * self.q[0].cos() + L2 * c01;
                let jx1 = -L2 * s01;
                let jy1 = L2 * c01;
                j0 = jx0 * fx + jy0 * fy;
                j1 = jx1 * fx + jy1 * fy;
            }
        }

        self.dq[0] += (tau0 + j0) * DT;
        self.dq[1] += (tau1 + j1) * DT;
        self.q[0] += self.dq[0] * DT;
        self.q[1] += self.dq[1] * DT;

        let dist = self.distance_to_goal();
        let ctrl = (a[0] * a[0] + a[1] * a[1]) * CTRL_COST;
        let bonus = if dist < BONUS_RADIUS { 0.5 } else { 0.0 };
        let reward = -dist - ctrl + bonus;

        self.t += 1;
        self.observation_into(obs_out);
        (reward, self.t >= HORIZON)
    }

    fn set_perturbation(&mut self, p: Option<Perturbation>) {
        self.perturbation = p;
    }

    fn horizon(&self) -> usize {
        HORIZON
    }

    fn name(&self) -> &'static str {
        "reacher"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(x: f64, y: f64) -> TaskParam {
        TaskParam {
            family: TaskFamily::Position,
            value: x,
            value2: y,
            id: 0,
        }
    }

    /// Oracle: Jacobian-transpose PD toward the goal.
    fn oracle_action(env: &Reacher) -> Vec<f32> {
        let (tx, ty) = env.tip();
        let ex = env.goal.0 - tx;
        let ey = env.goal.1 - ty;
        let s01 = (env.q[0] + env.q[1]).sin();
        let c01 = (env.q[0] + env.q[1]).cos();
        let jx0 = -L1 * env.q[0].sin() - L2 * s01;
        let jy0 = L1 * env.q[0].cos() + L2 * c01;
        let jx1 = -L2 * s01;
        let jy1 = L2 * c01;
        let kp = 10.0;
        let kd = 2.0;
        vec![
            (kp * (jx0 * ex + jy0 * ey) - kd * env.dq[0]).clamp(-1.0, 1.0),
            (kp * (jx1 * ex + jy1 * ey) - kd * env.dq[1]).clamp(-1.0, 1.0),
        ]
    }

    #[test]
    fn oracle_reaches_goals() {
        for (gx, gy) in [(0.5, 0.3), (-0.4, 0.4), (0.2, -0.6)] {
            let mut env = Reacher::new();
            let mut rng = Pcg64::new(1, 0);
            env.reset(&task(gx, gy), &mut rng);
            for _ in 0..HORIZON {
                let a = oracle_action(&env);
                env.step(&a);
            }
            let d = env.distance_to_goal();
            assert!(d < 0.12, "goal ({gx},{gy}): final distance {d}");
        }
    }

    #[test]
    fn kinematics_reach_matches_goal_radius() {
        assert!((L1 + L2 - GOAL_RADIUS as f32).abs() < 1e-6);
        let mut env = Reacher::new();
        env.q = [0.0, 0.0];
        let (x, y) = env.tip();
        assert!((x - (L1 + L2)).abs() < 1e-6);
        assert!(y.abs() < 1e-6);
    }

    #[test]
    fn settling_bonus_rewards_proximity() {
        let mut env = Reacher::new();
        let mut rng = Pcg64::new(2, 0);
        env.reset(&task(0.79, 0.0), &mut rng);
        // start almost at the goal (arm along +x reaches (0.8, 0))
        let (_, r_near, _) = env.step(&[0.0, 0.0]);
        let mut env2 = Reacher::new();
        env2.reset(&task(-0.5, 0.5), &mut rng);
        let (_, r_far, _) = env2.step(&[0.0, 0.0]);
        assert!(r_near > r_far + 0.5);
    }

    #[test]
    fn frozen_shoulder_hurts() {
        let run = |broken: bool| {
            let mut env = Reacher::new();
            let mut rng = Pcg64::new(3, 0);
            env.reset(&task(-0.4, 0.4), &mut rng);
            if broken {
                env.set_perturbation(Some(Perturbation::leg_failure(vec![0])));
            }
            let mut total = 0.0;
            for _ in 0..HORIZON {
                let a = oracle_action(&env);
                let (_, r, _) = env.step(&a);
                total += r;
            }
            total
        };
        assert!(run(true) < run(false) - 1.0);
    }

    #[test]
    fn dynamics_bounded_under_bang_bang() {
        let mut env = Reacher::new();
        let mut rng = Pcg64::new(4, 0);
        env.reset(&task(0.3, 0.3), &mut rng);
        for t in 0..1000 {
            let a = if t % 2 == 0 { [1.0, -1.0] } else { [-1.0, 1.0] };
            let (obs, r, _) = env.step(&a);
            assert!(r.is_finite());
            for o in &obs {
                assert!(o.is_finite(), "obs not finite at t={t}");
            }
            assert!(env.dq[0].abs() < 50.0 && env.dq[1].abs() < 50.0);
        }
    }
}
