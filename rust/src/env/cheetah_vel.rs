//! CheetahVel — planar runner tracking a commanded forward velocity
//! (the Brax *halfcheetah* velocity-generalization task, §IV-A).
//!
//! Model: a body constrained to the x axis with six "joint" actuators
//! whose useful thrust depends on a gait phase — the actuators are
//! arranged in two tripods and thrust is produced when an actuator is
//! driven *in phase* with its tripod's stance window (driving against
//! the phase wastes energy and brakes). This preserves the essential
//! difficulty of halfcheetah velocity tracking: the controller cannot
//! just push a constant; it must produce a rhythmic, coordinated pattern
//! whose amplitude modulates speed.
//!
//! Reward per step = −|v − v*| − control cost (the standard velocity-
//! task shaping), so per-step reward is ≤ 0 and perfect tracking → 0.

use super::perturb::Perturbation;
use super::protocol::{TaskFamily, TaskParam};
use super::Env;
use crate::util::rng::Pcg64;

const N_JOINTS: usize = 6;
const DT: f32 = 0.05;
const MASS: f32 = 1.0;
const DRAG: f32 = 0.8;
const THRUST_GAIN: f32 = 1.6;
const BRAKE_GAIN: f32 = 0.4;
const CTRL_COST: f32 = 0.02;
const HORIZON: usize = 200;
/// Gait oscillator frequency (rad per step).
const PHASE_RATE: f32 = 0.45;

/// Planar runner tracking a commanded forward velocity (see the module
/// docs for the tripod-gait dynamics model).
pub struct CheetahVel {
    x: f32,
    v: f32,
    phase: f32,
    v_target: f32,
    t: usize,
    perturbation: Option<Perturbation>,
}

impl CheetahVel {
    /// Environment at rest with a 1 m/s default target velocity.
    pub fn new() -> Self {
        CheetahVel {
            x: 0.0,
            v: 0.0,
            phase: 0.0,
            v_target: 1.0,
            t: 0,
            perturbation: None,
        }
    }

    /// Stance weight of joint `k` at the current phase: tripod A
    /// (joints 0,2,4) is in stance for sin(φ) > 0, tripod B (1,3,5) for
    /// sin(φ) < 0; weight is the positive half-wave.
    fn stance(&self, k: usize) -> f32 {
        let s = self.phase.sin();
        if k % 2 == 0 {
            s.max(0.0)
        } else {
            (-s).max(0.0)
        }
    }

    /// Write the current observation into `out` (cleared first) — the
    /// allocation-free primitive both [`Env::step_into`] and the
    /// allocating wrappers share, so their values are identical.
    fn observation_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.extend_from_slice(&[
            self.v,
            self.v_target,
            self.v_target - self.v,
            self.phase.sin(),
            self.phase.cos(),
            1.0, // bias
        ]);
        if let Some(p) = &self.perturbation {
            p.filter_obs(out);
        }
    }

    fn observation(&self) -> Vec<f32> {
        let mut obs = Vec::with_capacity(6);
        self.observation_into(&mut obs);
        obs
    }
}

impl Default for CheetahVel {
    fn default() -> Self {
        Self::new()
    }
}

impl Env for CheetahVel {
    fn obs_dim(&self) -> usize {
        6
    }

    fn act_dim(&self) -> usize {
        N_JOINTS
    }

    fn reset(&mut self, task: &TaskParam, rng: &mut Pcg64) -> Vec<f32> {
        assert_eq!(task.family, TaskFamily::Velocity, "CheetahVel needs a velocity task");
        self.x = 0.0;
        self.v = 0.0;
        self.phase = rng.uniform_range(0.0, std::f64::consts::TAU) as f32;
        self.v_target = task.value as f32;
        self.t = 0;
        self.perturbation = None;
        self.observation()
    }

    fn step_into(&mut self, action: &[f32], obs_out: &mut Vec<f32>) -> (f32, bool) {
        assert_eq!(action.len(), N_JOINTS);
        // Fixed-size clamp buffer: no per-step heap allocation.
        let mut a = [0.0f32; N_JOINTS];
        for (dst, &x) in a.iter_mut().zip(action) {
            *dst = x.clamp(-1.0, 1.0);
        }
        if let Some(p) = &self.perturbation {
            p.filter_action(&mut a);
        }

        // Thrust: in-stance drive propels; out-of-stance drive brakes.
        let mut thrust = 0.0f32;
        for (k, &ak) in a.iter().enumerate() {
            let w = self.stance(k);
            thrust += THRUST_GAIN * w * ak.max(0.0);
            thrust -= BRAKE_GAIN * (1.0 - w) * ak.abs();
        }
        let mut force = thrust - DRAG * self.v;
        if let Some(p) = &self.perturbation {
            force += p.external_force().0;
        }

        self.v += force / MASS * DT;
        self.x += self.v * DT;
        self.phase += PHASE_RATE;
        if self.phase > std::f32::consts::TAU {
            self.phase -= std::f32::consts::TAU;
        }

        let track_err = (self.v - self.v_target).abs();
        let ctrl: f32 = a.iter().map(|x| x * x).sum::<f32>() * CTRL_COST;
        let reward = -track_err - ctrl;

        self.t += 1;
        self.observation_into(obs_out);
        (reward, self.t >= HORIZON)
    }

    fn set_perturbation(&mut self, p: Option<Perturbation>) {
        self.perturbation = p;
    }

    fn horizon(&self) -> usize {
        HORIZON
    }

    fn name(&self) -> &'static str {
        "cheetah-vel"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(v: f64) -> TaskParam {
        TaskParam {
            family: TaskFamily::Velocity,
            value: v,
            value2: 0.0,
            id: 0,
        }
    }

    /// Oracle: proportional drive on the in-stance tripod.
    fn oracle_action(obs: &[f32]) -> Vec<f32> {
        let v_err = obs[2];
        let sin_phase = obs[3];
        let drive = (v_err * 1.5).clamp(0.0, 1.0);
        (0..N_JOINTS)
            .map(|k| {
                let in_stance = if k % 2 == 0 { sin_phase > 0.0 } else { sin_phase < 0.0 };
                if in_stance {
                    drive
                } else {
                    0.0
                }
            })
            .collect()
    }

    #[test]
    fn oracle_tracks_targets() {
        for v in [1.0, 2.5, 4.0] {
            let mut env = CheetahVel::new();
            let mut rng = Pcg64::new(1, 0);
            let mut obs = env.reset(&task(v), &mut rng);
            let mut late_err = 0.0;
            for t in 0..HORIZON {
                let a = oracle_action(&obs);
                let (o, _, _) = env.step(&a);
                obs = o;
                if t >= HORIZON - 50 {
                    late_err += (env.v - env.v_target).abs();
                }
            }
            let mean_err = late_err / 50.0;
            assert!(mean_err < 0.8, "target {v}: steady-state err {mean_err}");
        }
    }

    #[test]
    fn constant_full_drive_is_suboptimal() {
        // Driving all joints at 1 regardless of phase brakes against the
        // swing tripod; the gait-aware oracle must do better.
        let score = |gait_aware: bool| {
            let mut env = CheetahVel::new();
            let mut rng = Pcg64::new(2, 0);
            let mut obs = env.reset(&task(2.0), &mut rng);
            let mut total = 0.0;
            for _ in 0..HORIZON {
                let a = if gait_aware {
                    oracle_action(&obs)
                } else {
                    vec![1.0; N_JOINTS]
                };
                let (o, r, _) = env.step(&a);
                obs = o;
                total += r;
            }
            total
        };
        assert!(score(true) > score(false) + 5.0);
    }

    #[test]
    fn zero_action_decays_to_rest() {
        let mut env = CheetahVel::new();
        let mut rng = Pcg64::new(3, 0);
        env.reset(&task(1.0), &mut rng);
        env.v = 3.0;
        for _ in 0..HORIZON {
            env.step(&vec![0.0; N_JOINTS]);
        }
        assert!(env.v.abs() < 0.1);
    }

    #[test]
    fn perfect_tracking_reward_near_zero() {
        let mut env = CheetahVel::new();
        let mut rng = Pcg64::new(4, 0);
        env.reset(&task(0.5), &mut rng);
        // force exact tracking, measure the reward ceiling
        env.v = 0.5;
        let (_, r, _) = env.step(&vec![0.0; N_JOINTS]);
        assert!(r > -0.2, "near-perfect tracking reward {r}");
    }

    #[test]
    fn weak_motors_reduce_top_speed() {
        let run = |gain: Option<f32>| {
            let mut env = CheetahVel::new();
            let mut rng = Pcg64::new(5, 0);
            let mut obs = env.reset(&task(4.5), &mut rng);
            if let Some(g) = gain {
                env.set_perturbation(Some(Perturbation::weak_motors(g)));
            }
            for _ in 0..HORIZON {
                let a = oracle_action(&obs);
                let (o, _, _) = env.step(&a);
                obs = o;
            }
            env.v
        };
        assert!(run(Some(0.3)) < run(None) - 0.3);
    }

    #[test]
    fn dynamics_bounded() {
        let mut env = CheetahVel::new();
        let mut rng = Pcg64::new(6, 0);
        env.reset(&task(4.5), &mut rng);
        for _ in 0..1000 {
            let (obs, r, _) = env.step(&vec![1.0; N_JOINTS]);
            assert!(r.is_finite());
            for o in &obs {
                assert!(o.is_finite() && o.abs() < 50.0);
            }
        }
    }
}
