//! Mid-episode perturbations (§I, §II-B: "sudden changes in morphology,
//! novel environmental dynamics, or unexpected external forces", with
//! "simulated leg failure" as the paper's canonical example).
//!
//! A [`Perturbation`] is applied by the coordinator at a chosen timestep;
//! the environment then filters every action/dynamics update through it
//! until cleared. This is the stressor the online plasticity rule must
//! compensate for in EXP-E2E.

/// What a perturbation does to the plant (the failure taxonomy of §II-B).
#[derive(Clone, Debug, PartialEq)]
pub enum PerturbationKind {
    /// Actuator(s) produce zero torque — "leg failure".
    ActuatorFailure { indices: Vec<usize> },
    /// All actuator outputs scaled by a factor (weakness / gain error).
    ActuatorGain { factor: f32 },
    /// Constant external force in the world frame (wind / payload shift).
    ExternalForce { fx: f32, fy: f32 },
    /// Action channels permuted (cable swap / morphology change).
    ActionRemap { map: Vec<usize> },
    /// Sensor bias added to every observation component.
    SensorBias { bias: f32 },
}

/// A labelled mid-episode stressor, applied by the coordinator at a
/// chosen timestep and filtered through by the environment until
/// cleared.
#[derive(Clone, Debug, PartialEq)]
pub struct Perturbation {
    /// The concrete failure mode.
    pub kind: PerturbationKind,
    /// Short stable label for CSV output and logs.
    pub label: &'static str,
}

impl Perturbation {
    /// Zero the torque of the listed actuators ("simulated leg failure",
    /// the paper's canonical recovery scenario).
    pub fn leg_failure(indices: Vec<usize>) -> Self {
        Perturbation {
            kind: PerturbationKind::ActuatorFailure { indices },
            label: "leg-failure",
        }
    }

    /// Scale every actuator output by `factor` (weakness / gain error).
    pub fn weak_motors(factor: f32) -> Self {
        Perturbation {
            kind: PerturbationKind::ActuatorGain { factor },
            label: "weak-motors",
        }
    }

    /// Constant world-frame external force (wind / payload shift).
    pub fn wind(fx: f32, fy: f32) -> Self {
        Perturbation {
            kind: PerturbationKind::ExternalForce { fx, fy },
            label: "wind",
        }
    }

    /// Permute the action channels (cable swap / morphology change):
    /// output `i` is driven by commanded channel `map[i]`.
    pub fn remap(map: Vec<usize>) -> Self {
        Perturbation {
            kind: PerturbationKind::ActionRemap { map },
            label: "action-remap",
        }
    }

    /// Add a constant bias to every observation component.
    pub fn sensor_bias(bias: f32) -> Self {
        Perturbation {
            kind: PerturbationKind::SensorBias { bias },
            label: "sensor-bias",
        }
    }

    /// Transform a raw action vector in place. Allocation-free except
    /// for [`PerturbationKind::ActionRemap`], whose permutation scratch
    /// copies the input (noted in [`crate::env::Env::step_into`]).
    pub fn filter_action(&self, action: &mut [f32]) {
        match &self.kind {
            PerturbationKind::ActuatorFailure { indices } => {
                for &i in indices {
                    if i < action.len() {
                        action[i] = 0.0;
                    }
                }
            }
            PerturbationKind::ActuatorGain { factor } => {
                for a in action.iter_mut() {
                    *a *= factor;
                }
            }
            PerturbationKind::ActionRemap { map } => {
                let orig = action.to_vec();
                for (i, &src) in map.iter().enumerate() {
                    if i < action.len() && src < orig.len() {
                        action[i] = orig[src];
                    }
                }
            }
            _ => {}
        }
    }

    /// External force to inject into the dynamics, if any.
    pub fn external_force(&self) -> (f32, f32) {
        match self.kind {
            PerturbationKind::ExternalForce { fx, fy } => (fx, fy),
            _ => (0.0, 0.0),
        }
    }

    /// Transform an observation in place.
    pub fn filter_obs(&self, obs: &mut [f32]) {
        if let PerturbationKind::SensorBias { bias } = self.kind {
            for o in obs.iter_mut() {
                *o += bias;
            }
        }
    }

    /// Encode back into the [`Perturbation::parse`] grammar, e.g.
    /// `leg:0,2`, `gain:0.3`, `wind:1,-0.5`. Floats use Rust's shortest
    /// round-trip `Display`, so `parse(p.spec()) == p` bit-exactly —
    /// the encode half of the job-spec wire round-trip
    /// (`coordinator/jobs.rs`).
    pub fn spec(&self) -> String {
        fn join_usize(v: &[usize]) -> String {
            let mut s = String::new();
            for (i, x) in v.iter().enumerate() {
                if i > 0 {
                    s.push(',');
                }
                s.push_str(&x.to_string());
            }
            s
        }
        match &self.kind {
            PerturbationKind::ActuatorFailure { indices } => {
                format!("leg:{}", join_usize(indices))
            }
            PerturbationKind::ActuatorGain { factor } => format!("gain:{factor}"),
            PerturbationKind::ExternalForce { fx, fy } => format!("wind:{fx},{fy}"),
            PerturbationKind::ActionRemap { map } => format!("remap:{}", join_usize(map)),
            PerturbationKind::SensorBias { bias } => format!("bias:{bias}"),
        }
    }

    /// Parse from CLI syntax, e.g. `leg:0,2`, `gain:0.3`, `wind:1.0,-0.5`,
    /// `remap:1,0,3,2`, `bias:0.2`.
    pub fn parse(spec: &str) -> Result<Perturbation, String> {
        let (kind, args) = spec.split_once(':').unwrap_or((spec, ""));
        match kind {
            "leg" => {
                let indices: Result<Vec<usize>, _> =
                    args.split(',').map(|s| s.trim().parse()).collect();
                Ok(Perturbation::leg_failure(
                    indices.map_err(|e| format!("bad leg indices: {e}"))?,
                ))
            }
            "gain" => Ok(Perturbation::weak_motors(
                args.parse().map_err(|e| format!("bad gain: {e}"))?,
            )),
            "wind" => {
                let parts: Vec<&str> = args.split(',').collect();
                if parts.len() != 2 {
                    return Err("wind needs fx,fy".into());
                }
                Ok(Perturbation::wind(
                    parts[0].trim().parse().map_err(|e| format!("bad fx: {e}"))?,
                    parts[1].trim().parse().map_err(|e| format!("bad fy: {e}"))?,
                ))
            }
            "remap" => {
                let map: Result<Vec<usize>, _> =
                    args.split(',').map(|s| s.trim().parse()).collect();
                Ok(Perturbation::remap(
                    map.map_err(|e| format!("bad remap: {e}"))?,
                ))
            }
            "bias" => Ok(Perturbation::sensor_bias(
                args.parse().map_err(|e| format!("bad bias: {e}"))?,
            )),
            _ => Err(format!("unknown perturbation {kind:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn leg_failure_zeroes_selected() {
        let p = Perturbation::leg_failure(vec![0, 2]);
        let mut a = vec![1.0, 1.0, 1.0, 1.0];
        p.filter_action(&mut a);
        assert_eq!(a, vec![0.0, 1.0, 0.0, 1.0]);
    }

    #[test]
    fn gain_scales_all() {
        let p = Perturbation::weak_motors(0.5);
        let mut a = vec![1.0, -2.0];
        p.filter_action(&mut a);
        assert_eq!(a, vec![0.5, -1.0]);
    }

    #[test]
    fn remap_permutes() {
        let p = Perturbation::remap(vec![1, 0]);
        let mut a = vec![3.0, 7.0];
        p.filter_action(&mut a);
        assert_eq!(a, vec![7.0, 3.0]);
    }

    #[test]
    fn wind_reports_force() {
        let p = Perturbation::wind(1.0, -0.5);
        assert_eq!(p.external_force(), (1.0, -0.5));
        let mut a = vec![1.0];
        p.filter_action(&mut a); // no action effect
        assert_eq!(a, vec![1.0]);
    }

    #[test]
    fn sensor_bias_shifts_obs() {
        let p = Perturbation::sensor_bias(0.25);
        let mut o = vec![0.0, 1.0];
        p.filter_obs(&mut o);
        assert_eq!(o, vec![0.25, 1.25]);
    }

    #[test]
    fn parse_round_trips() {
        assert_eq!(
            Perturbation::parse("leg:0,2").unwrap(),
            Perturbation::leg_failure(vec![0, 2])
        );
        assert_eq!(
            Perturbation::parse("gain:0.3").unwrap(),
            Perturbation::weak_motors(0.3)
        );
        assert_eq!(
            Perturbation::parse("wind:1.0,-0.5").unwrap(),
            Perturbation::wind(1.0, -0.5)
        );
        assert!(Perturbation::parse("bogus:1").is_err());
        assert!(Perturbation::parse("leg:x").is_err());
    }

    #[test]
    fn spec_encodes_back_into_parse_grammar() {
        let menu = [
            Perturbation::leg_failure(vec![0, 2]),
            Perturbation::weak_motors(0.3),
            Perturbation::wind(1.0, -0.5),
            Perturbation::remap(vec![1, 0, 3, 2]),
            Perturbation::sensor_bias(0.2),
        ];
        for p in menu {
            let enc = p.spec();
            assert_eq!(Perturbation::parse(&enc).unwrap(), p, "spec {enc}");
        }
    }
}
