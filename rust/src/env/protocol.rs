//! The paper's generalization protocol (§IV-A): train on a sparse grid of
//! task parameters, evaluate on a dense grid of *novel* parameters.
//!
//! - Direction (ant): train on 8 directions (every 45°), evaluate on the
//!   72 directions at 5° spacing **excluding** the 8 training ones.
//! - Velocity (halfcheetah): train on 8 target velocities, evaluate on 72
//!   unseen velocities interleaved over the same range.
//! - Position (ur5e reacher): goals sampled randomly; "train" tasks use
//!   one seed set, "eval" uses disjoint seeds.

use crate::util::rng::Pcg64;

/// The parametric task family an environment generalizes over (§IV-A).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskFamily {
    /// Ant: commanded locomotion direction (angle in radians).
    Direction,
    /// Halfcheetah: commanded forward velocity (m/s).
    Velocity,
    /// Reacher: goal position in the reachable disc.
    Position,
}

/// One task instance within a family.
#[derive(Clone, Debug, PartialEq)]
pub struct TaskParam {
    pub family: TaskFamily,
    /// Direction: angle in radians. Velocity: target speed (m/s).
    /// Position: goal index (expanded to coordinates by the env).
    pub value: f64,
    /// Optional second coordinate (Position: goal y; unused otherwise).
    pub value2: f64,
    /// Stable identifier for CSV output.
    pub id: usize,
}

/// Velocity range for the cheetah family (m/s).
pub const VEL_MIN: f64 = 0.5;
pub const VEL_MAX: f64 = 4.5;

/// Reacher goal disc radius (m) around the arm base.
pub const GOAL_RADIUS: f64 = 0.8;

/// The 8 training tasks of a family.
pub fn train_grid(family: TaskFamily) -> Vec<TaskParam> {
    match family {
        TaskFamily::Direction => (0..8)
            .map(|k| TaskParam {
                family,
                value: k as f64 * std::f64::consts::TAU / 8.0,
                value2: 0.0,
                id: k,
            })
            .collect(),
        TaskFamily::Velocity => (0..8)
            .map(|k| TaskParam {
                family,
                value: VEL_MIN + (VEL_MAX - VEL_MIN) * k as f64 / 7.0,
                value2: 0.0,
                id: k,
            })
            .collect(),
        TaskFamily::Position => goal_set(0xA5EED, 8, 0),
    }
}

/// The 72 evaluation tasks — all novel w.r.t. the training grid.
pub fn eval_grid(family: TaskFamily) -> Vec<TaskParam> {
    match family {
        TaskFamily::Direction => {
            // 80 directions at 4.5° spacing minus the 8 training ones
            // (every 10th) = 72 novel directions.
            (0..80)
                .filter(|k| k % 10 != 0)
                .enumerate()
                .map(|(i, k)| TaskParam {
                    family,
                    value: k as f64 * std::f64::consts::TAU / 80.0,
                    value2: 0.0,
                    id: 100 + i,
                })
                .collect()
        }
        TaskFamily::Velocity => {
            // 80 velocities evenly over the range minus the training 8.
            let train = train_grid(family);
            (0..80)
                .map(|k| VEL_MIN + (VEL_MAX - VEL_MIN) * k as f64 / 79.0)
                .filter(|v| {
                    train
                        .iter()
                        .all(|t| (t.value - v).abs() > 1e-6)
                })
                .take(72)
                .enumerate()
                .map(|(i, v)| TaskParam {
                    family,
                    value: v,
                    value2: 0.0,
                    id: 100 + i,
                })
                .collect()
        }
        TaskFamily::Position => goal_set(0xBEEF5, 72, 100),
    }
}

/// Random goal positions in an annulus (min 25% of max reach, so goals
/// are never trivially at the base).
fn goal_set(seed: u64, n: usize, id_base: usize) -> Vec<TaskParam> {
    let mut rng = Pcg64::new(seed, 31);
    (0..n)
        .map(|i| {
            let r = GOAL_RADIUS * (0.25 + 0.75 * rng.uniform());
            let th = rng.uniform_range(0.0, std::f64::consts::TAU);
            TaskParam {
                family: TaskFamily::Position,
                value: r * th.cos(),
                value2: r * th.sin(),
                id: id_base + i,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grids_have_paper_sizes() {
        for fam in [TaskFamily::Direction, TaskFamily::Velocity, TaskFamily::Position] {
            assert_eq!(train_grid(fam).len(), 8, "{fam:?} train");
            assert_eq!(eval_grid(fam).len(), 72, "{fam:?} eval");
        }
    }

    #[test]
    fn eval_directions_exclude_training() {
        let train = train_grid(TaskFamily::Direction);
        let eval = eval_grid(TaskFamily::Direction);
        for e in &eval {
            for t in &train {
                assert!(
                    (e.value - t.value).abs() > 1e-9,
                    "eval dir {} collides with train dir {}",
                    e.value,
                    t.value
                );
            }
        }
    }

    #[test]
    fn eval_velocities_exclude_training() {
        let train = train_grid(TaskFamily::Velocity);
        let eval = eval_grid(TaskFamily::Velocity);
        for e in &eval {
            for t in &train {
                assert!((e.value - t.value).abs() > 1e-9);
            }
        }
        for e in &eval {
            assert!(e.value >= VEL_MIN - 1e-9 && e.value <= VEL_MAX + 1e-9);
        }
    }

    #[test]
    fn position_train_eval_disjoint() {
        let train = train_grid(TaskFamily::Position);
        let eval = eval_grid(TaskFamily::Position);
        for e in &eval {
            for t in &train {
                let d = ((e.value - t.value).powi(2) + (e.value2 - t.value2).powi(2)).sqrt();
                assert!(d > 1e-6);
            }
        }
        // goals inside the annulus
        for g in train.iter().chain(eval.iter()) {
            let r = (g.value * g.value + g.value2 * g.value2).sqrt();
            assert!(r >= 0.25 * GOAL_RADIUS - 1e-9 && r <= GOAL_RADIUS + 1e-9);
        }
    }

    #[test]
    fn grids_deterministic() {
        assert_eq!(train_grid(TaskFamily::Position), train_grid(TaskFamily::Position));
        assert_eq!(eval_grid(TaskFamily::Direction), eval_grid(TaskFamily::Direction));
    }
}
