//! Continuous-control environments (Brax substitute — see DESIGN.md §2).
//!
//! The paper evaluates on three Brax tasks (§IV-A): *ant* trained on 8
//! target directions and evaluated on 72 novel ones, *halfcheetah*
//! trained on 8 target velocities and evaluated on 72 unseen ones, and a
//! *ur5e* reaching task with random goals. Brax is unavailable offline,
//! so this module implements physics substrates from scratch that
//! preserve what the experiment actually measures: **generalization of a
//! learned plasticity rule across a parametric task family**, plus online
//! recovery from actuator failure.
//!
//! All three are deterministic given (task, seed), time-discretized at
//! `dt`, with continuous observation/action spaces and per-step rewards.

pub mod ant_dir;
pub mod cheetah_vel;
pub mod perturb;
pub mod protocol;
pub mod reacher;

pub use ant_dir::AntDir;
pub use cheetah_vel::CheetahVel;
pub use perturb::{Perturbation, PerturbationKind};
pub use protocol::{eval_grid, train_grid, TaskFamily, TaskParam};
pub use reacher::Reacher;

use crate::util::rng::Pcg64;

/// A task-parameterized continuous-control environment.
pub trait Env: Send {
    /// Observation dimensionality.
    fn obs_dim(&self) -> usize;
    /// Action dimensionality (actions are clipped to [−1, 1] per dim).
    fn act_dim(&self) -> usize;
    /// Reset to the start state for task parameter `task`, seeded
    /// deterministically. Returns the initial observation.
    fn reset(&mut self, task: &TaskParam, rng: &mut Pcg64) -> Vec<f32>;
    /// Advance one control tick, writing the next observation into the
    /// caller's pooled buffer (cleared first). Returns (reward, done).
    ///
    /// This is the batched adaptation engine's hot path
    /// (`coordinator/batch_adapt.rs`): once the buffer is warm the
    /// built-in environments perform **zero heap allocations** per step
    /// (pinned by `tests/alloc_free_serving.rs`) — except under an
    /// `ActionRemap` perturbation, whose permutation scratch still
    /// allocates.
    fn step_into(&mut self, action: &[f32], obs_out: &mut Vec<f32>) -> (f32, bool);
    /// Advance one control tick. Returns (observation, reward, done).
    /// Convenience wrapper over [`Env::step_into`] that allocates a
    /// fresh observation vector (the cold path; values are identical).
    fn step(&mut self, action: &[f32]) -> (Vec<f32>, f32, bool) {
        let mut obs = Vec::with_capacity(self.obs_dim());
        let (reward, done) = self.step_into(action, &mut obs);
        (obs, reward, done)
    }
    /// Apply/clear a perturbation mid-episode (leg failure etc.).
    fn set_perturbation(&mut self, p: Option<Perturbation>);
    /// Episode length used by the paper-style evaluation.
    fn horizon(&self) -> usize;
    /// Human-readable name.
    fn name(&self) -> &'static str;
}

/// Environment registry keyed by CLI name.
pub fn make_env(name: &str) -> Option<Box<dyn Env>> {
    match name {
        "ant-dir" | "ant" => Some(Box::new(AntDir::new())),
        "cheetah-vel" | "halfcheetah" => Some(Box::new(CheetahVel::new())),
        "reacher" | "ur5e" => Some(Box::new(Reacher::new())),
        _ => None,
    }
}

/// The task family an environment name belongs to.
pub fn family_of(name: &str) -> Option<TaskFamily> {
    match name {
        "ant-dir" | "ant" => Some(TaskFamily::Direction),
        "cheetah-vel" | "halfcheetah" => Some(TaskFamily::Velocity),
        "reacher" | "ur5e" => Some(TaskFamily::Position),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_all_names() {
        for n in ["ant-dir", "ant", "cheetah-vel", "halfcheetah", "reacher", "ur5e"] {
            assert!(make_env(n).is_some(), "missing env {n}");
            assert!(family_of(n).is_some());
        }
        assert!(make_env("nope").is_none());
    }

    #[test]
    fn envs_obey_basic_contract() {
        let mut rng = Pcg64::new(0, 0);
        for name in ["ant-dir", "cheetah-vel", "reacher"] {
            let mut env = make_env(name).unwrap();
            let task = train_grid(family_of(name).unwrap())[0].clone();
            let obs = env.reset(&task, &mut rng);
            assert_eq!(obs.len(), env.obs_dim(), "{name} obs_dim");
            let action = vec![0.1; env.act_dim()];
            let (obs2, r, done) = env.step(&action);
            assert_eq!(obs2.len(), env.obs_dim());
            assert!(r.is_finite(), "{name} reward finite");
            assert!(!done, "{name} done on first step");
            assert!(env.horizon() > 10);
        }
    }

    #[test]
    fn step_into_matches_step_bitwise() {
        // The pooled-buffer step is the batched engine's hot path; it
        // must be value-identical to the allocating wrapper, with and
        // without a perturbation installed.
        for name in ["ant-dir", "cheetah-vel", "reacher"] {
            let mut a = make_env(name).unwrap();
            let mut b = make_env(name).unwrap();
            let task = train_grid(family_of(name).unwrap())[0].clone();
            let mut r1 = Pcg64::new(9, 0);
            let mut r2 = Pcg64::new(9, 0);
            a.reset(&task, &mut r1);
            b.reset(&task, &mut r2);
            a.set_perturbation(Some(Perturbation::leg_failure(vec![0])));
            b.set_perturbation(Some(Perturbation::leg_failure(vec![0])));
            let mut obs = Vec::new();
            for t in 0..25 {
                let action: Vec<f32> = (0..a.act_dim())
                    .map(|k| (((t + k) % 5) as f32) * 0.3 - 0.6)
                    .collect();
                let (o, r, d) = a.step(&action);
                let (r_into, d_into) = b.step_into(&action, &mut obs);
                assert_eq!(o, obs, "{name} obs diverged at t={t}");
                assert_eq!(r, r_into, "{name} reward diverged at t={t}");
                assert_eq!(d, d_into);
            }
        }
    }

    #[test]
    fn reset_is_deterministic_per_seed() {
        for name in ["ant-dir", "cheetah-vel", "reacher"] {
            let mut env = make_env(name).unwrap();
            let task = train_grid(family_of(name).unwrap())[1].clone();
            let mut r1 = Pcg64::new(7, 0);
            let mut r2 = Pcg64::new(7, 0);
            let o1 = env.reset(&task, &mut r1);
            let o2 = env.reset(&task, &mut r2);
            assert_eq!(o1, o2, "{name} reset not deterministic");
        }
    }
}
