//! AntDir — planar quadruped locomotion toward a commanded direction
//! (the Brax *ant* direction-generalization task, §IV-A).
//!
//! Model: a rigid body in the plane with four legs modeled as thrust
//! generators mounted at 45°/135°/225°/315° in the body frame. Each leg's
//! action in [−1, 1] produces thrust along its mount direction plus a yaw
//! torque proportional to its tangential lever arm. Linear/angular drag
//! make velocities bounded; the controller must coordinate legs to move
//! the body along the commanded world-frame direction.
//!
//! Reward per step = (body velocity · target direction) − control cost,
//! the same shaping Brax's `ant` direction task uses. A leg failure
//! (actuator zeroed) breaks the thrust symmetry, so sustained progress
//! requires online compensation by the remaining legs — the paper's
//! recovery scenario.

use super::perturb::Perturbation;
use super::protocol::{TaskFamily, TaskParam};
use super::Env;
use crate::util::rng::Pcg64;

const N_LEGS: usize = 4;
const DT: f32 = 0.05;
const MASS: f32 = 1.0;
const INERTIA: f32 = 0.2;
const LIN_DRAG: f32 = 1.2;
const ANG_DRAG: f32 = 1.5;
const THRUST_GAIN: f32 = 3.0;
const TORQUE_GAIN: f32 = 0.6;
const CTRL_COST: f32 = 0.05;
const HORIZON: usize = 200;

/// Planar quadruped locomotion toward a commanded direction (see the
/// module docs for the dynamics model).
pub struct AntDir {
    // body state (world frame)
    x: f32,
    y: f32,
    vx: f32,
    vy: f32,
    heading: f32,
    omega: f32,
    target_dir: f32,
    t: usize,
    perturbation: Option<Perturbation>,
    /// Leg mount angles in the body frame.
    leg_angles: [f32; N_LEGS],
}

impl AntDir {
    /// Environment at the origin, at rest, heading +x, target direction 0.
    pub fn new() -> Self {
        AntDir {
            x: 0.0,
            y: 0.0,
            vx: 0.0,
            vy: 0.0,
            heading: 0.0,
            omega: 0.0,
            target_dir: 0.0,
            t: 0,
            perturbation: None,
            leg_angles: [
                std::f32::consts::FRAC_PI_4,
                3.0 * std::f32::consts::FRAC_PI_4,
                5.0 * std::f32::consts::FRAC_PI_4,
                7.0 * std::f32::consts::FRAC_PI_4,
            ],
        }
    }

    /// Write the current observation into `out` (cleared first) — the
    /// allocation-free primitive both [`Env::step_into`] and the
    /// allocating wrappers share, so their values are identical.
    fn observation_into(&self, out: &mut Vec<f32>) {
        // Direction error expressed in the body frame so the policy can
        // be rotation-equivariant; plus egocentric velocities.
        let err = angle_wrap(self.target_dir - self.heading);
        let (sh, ch) = self.heading.sin_cos();
        // world→body rotation
        let vbx = ch * self.vx + sh * self.vy;
        let vby = -sh * self.vx + ch * self.vy;
        let speed = (self.vx * self.vx + self.vy * self.vy).sqrt();
        out.clear();
        out.extend_from_slice(&[
            err.cos(),
            err.sin(),
            vbx,
            vby,
            self.omega,
            speed,
            // progress rate along the target direction
            self.vx * self.target_dir.cos() + self.vy * self.target_dir.sin(),
            1.0, // bias input
        ]);
        if let Some(p) = &self.perturbation {
            p.filter_obs(out);
        }
    }

    fn observation(&self) -> Vec<f32> {
        let mut obs = Vec::with_capacity(8);
        self.observation_into(&mut obs);
        obs
    }
}

impl Default for AntDir {
    fn default() -> Self {
        Self::new()
    }
}

impl Env for AntDir {
    fn obs_dim(&self) -> usize {
        8
    }

    fn act_dim(&self) -> usize {
        N_LEGS
    }

    fn reset(&mut self, task: &TaskParam, rng: &mut Pcg64) -> Vec<f32> {
        assert_eq!(task.family, TaskFamily::Direction, "AntDir needs a direction task");
        self.x = 0.0;
        self.y = 0.0;
        self.vx = 0.0;
        self.vy = 0.0;
        self.omega = 0.0;
        // Small heading jitter so the rule cannot memorize an exact pose.
        self.heading = (rng.uniform_range(-0.1, 0.1)) as f32;
        self.target_dir = task.value as f32;
        self.t = 0;
        self.perturbation = None;
        self.observation()
    }

    fn step_into(&mut self, action: &[f32], obs_out: &mut Vec<f32>) -> (f32, bool) {
        assert_eq!(action.len(), N_LEGS);
        // Fixed-size clamp buffer: no per-step heap allocation.
        let mut a = [0.0f32; N_LEGS];
        for (dst, &x) in a.iter_mut().zip(action) {
            *dst = x.clamp(-1.0, 1.0);
        }
        if let Some(p) = &self.perturbation {
            p.filter_action(&mut a);
        }

        // Legs: thrust along mount direction (body frame) + yaw torque.
        let mut fbx = 0.0f32;
        let mut fby = 0.0f32;
        let mut torque = 0.0f32;
        for (k, &ak) in a.iter().enumerate() {
            let ang = self.leg_angles[k];
            fbx += THRUST_GAIN * ak * ang.cos();
            fby += THRUST_GAIN * ak * ang.sin();
            // diagonal pairs twist in opposite senses
            let sense = if k % 2 == 0 { 1.0 } else { -1.0 };
            torque += TORQUE_GAIN * sense * ak;
        }

        // body→world rotation
        let (sh, ch) = self.heading.sin_cos();
        let mut fx = ch * fbx - sh * fby;
        let mut fy = sh * fbx + ch * fby;
        if let Some(p) = &self.perturbation {
            let (ex, ey) = p.external_force();
            fx += ex;
            fy += ey;
        }
        fx -= LIN_DRAG * self.vx;
        fy -= LIN_DRAG * self.vy;
        torque -= ANG_DRAG * self.omega;

        self.vx += fx / MASS * DT;
        self.vy += fy / MASS * DT;
        self.omega += torque / INERTIA * DT;
        self.x += self.vx * DT;
        self.y += self.vy * DT;
        self.heading = angle_wrap(self.heading + self.omega * DT);

        let progress = self.vx * self.target_dir.cos() + self.vy * self.target_dir.sin();
        let ctrl: f32 = a.iter().map(|x| x * x).sum::<f32>() * CTRL_COST;
        let reward = progress - ctrl;

        self.t += 1;
        self.observation_into(obs_out);
        (reward, self.t >= HORIZON)
    }

    fn set_perturbation(&mut self, p: Option<Perturbation>) {
        self.perturbation = p;
    }

    fn horizon(&self) -> usize {
        HORIZON
    }

    fn name(&self) -> &'static str {
        "ant-dir"
    }
}

fn angle_wrap(a: f32) -> f32 {
    let mut a = a % std::f32::consts::TAU;
    if a > std::f32::consts::PI {
        a -= std::f32::consts::TAU;
    } else if a < -std::f32::consts::PI {
        a += std::f32::consts::TAU;
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::protocol::train_grid;

    fn task(dir_deg: f64) -> TaskParam {
        TaskParam {
            family: TaskFamily::Direction,
            value: dir_deg.to_radians(),
            value2: 0.0,
            id: 0,
        }
    }

    /// Oracle controller: thrust legs toward the direction error.
    fn oracle_action(obs: &[f32]) -> Vec<f32> {
        let (cos_e, sin_e) = (obs[0], obs[1]);
        // command a body-frame force along the error direction
        let angles = [
            std::f32::consts::FRAC_PI_4,
            3.0 * std::f32::consts::FRAC_PI_4,
            5.0 * std::f32::consts::FRAC_PI_4,
            7.0 * std::f32::consts::FRAC_PI_4,
        ];
        angles
            .iter()
            .map(|a| (cos_e * a.cos() + sin_e * a.sin()).clamp(-1.0, 1.0))
            .collect()
    }

    #[test]
    fn oracle_moves_along_target() {
        for dir in [0.0, 90.0, 215.0] {
            let mut env = AntDir::new();
            let mut rng = Pcg64::new(1, 0);
            let mut obs = env.reset(&task(dir), &mut rng);
            let mut total = 0.0;
            for _ in 0..HORIZON {
                let a = oracle_action(&obs);
                let (o, r, _) = env.step(&a);
                obs = o;
                total += r;
            }
            assert!(total > 50.0, "oracle reward {total} for dir {dir}");
            // displacement roughly along target
            let disp = (env.x * (dir as f32).to_radians().cos()
                + env.y * (dir as f32).to_radians().sin()) as f64;
            assert!(disp > 1.0, "displacement {disp}");
        }
    }

    #[test]
    fn zero_action_earns_nothing() {
        let mut env = AntDir::new();
        let mut rng = Pcg64::new(2, 0);
        env.reset(&task(0.0), &mut rng);
        let mut total = 0.0;
        for _ in 0..50 {
            let (_, r, _) = env.step(&[0.0; 4]);
            total += r;
        }
        assert!(total.abs() < 1.0);
    }

    #[test]
    fn leg_failure_hurts_oracle() {
        let run = |perturb: bool| {
            let mut env = AntDir::new();
            let mut rng = Pcg64::new(3, 0);
            let mut obs = env.reset(&task(0.0), &mut rng);
            if perturb {
                env.set_perturbation(Some(Perturbation::leg_failure(vec![0, 1])));
            }
            let mut total = 0.0;
            for _ in 0..HORIZON {
                let a = oracle_action(&obs);
                let (o, r, _) = env.step(&a);
                obs = o;
                total += r;
            }
            total
        };
        let healthy = run(false);
        let broken = run(true);
        assert!(
            broken < healthy * 0.8,
            "failure should cost reward: {broken} vs {healthy}"
        );
    }

    #[test]
    fn episode_terminates_at_horizon() {
        let mut env = AntDir::new();
        let mut rng = Pcg64::new(4, 0);
        env.reset(&train_grid(TaskFamily::Direction)[0], &mut rng);
        let mut done = false;
        let mut steps = 0;
        while !done {
            let (_, _, d) = env.step(&[0.5; 4]);
            done = d;
            steps += 1;
            assert!(steps <= HORIZON);
        }
        assert_eq!(steps, HORIZON);
    }

    #[test]
    fn dynamics_are_bounded() {
        let mut env = AntDir::new();
        let mut rng = Pcg64::new(5, 0);
        env.reset(&task(45.0), &mut rng);
        for _ in 0..500 {
            let (obs, r, _) = env.step(&[1.0, -1.0, 1.0, -1.0]);
            assert!(r.is_finite());
            for o in &obs {
                assert!(o.is_finite() && o.abs() < 100.0);
            }
        }
    }

    #[test]
    fn angle_wrap_stays_in_pi() {
        for a in [-10.0f32, -3.2, 0.0, 3.2, 10.0, 100.0] {
            let w = angle_wrap(a);
            assert!((-std::f32::consts::PI..=std::f32::consts::PI).contains(&w));
        }
    }
}
