//! MNIST online-learning demo (the Table II workload, reduced budget):
//! the learnable FireFly-P rule vs. the fixed pair-based STDP baseline
//! on the synthetic digit corpus, with the end-to-end (inference +
//! learning) FPS estimated by the cycle-accurate FPGA model.
//!
//! Run: `cargo run --release --example mnist_online_learning`

use firefly_p::fpga::resources::NetGeometry;
use firefly_p::fpga::HwConfig;
use firefly_p::mnist::{generate, MnistConfig, OnlineMnist, UpdateRule};

fn main() {
    println!("=== MNIST online learning (Table II workload, synthetic corpus) ===\n");
    let train = generate(300, 1);
    let test = generate(100, 2);

    let cfg = MnistConfig {
        hidden: 256,
        k_winners: 8,
        t_present: 20,
        ..Default::default()
    };

    for (name, rule) in [
        ("FireFly-P learnable rule", UpdateRule::learnable_default()),
        ("pair-based STDP baseline", UpdateRule::pair_stdp_default()),
    ] {
        let mut m = OnlineMnist::new(cfg.clone(), rule);
        print!("{name:<28}");
        for epoch in 0..4 {
            m.train_epoch(&train);
            print!(" e{epoch}:{:.2}", m.accuracy(&test));
        }
        println!();
    }

    // End-to-end FPS at the paper's geometry from the cycle model:
    // per-timestep cycles ≈ L1 update (dominant) with overlap, ×
    // t_present timesteps per frame, at 200 MHz.
    let hw = HwConfig::default();
    let geo = NetGeometry::mnist();
    let l1_syn = geo.n_in * geo.n_hidden;
    let l2_syn = geo.n_hidden * geo.n_out;
    let update_cycles = (l1_syn + l2_syn).div_ceil(hw.syn_per_cycle) + 2 * hw.plast_pipe_depth;
    let t_present = 30; // paper's ~31 timesteps/frame at 32 FPS
    let frame_cycles = (update_cycles * t_present) as f64;
    let fps = hw.clock_mhz * 1e6 / frame_cycles;
    println!(
        "\nFPGA model (784-1024-10, {} syn/cycle, {} MHz): {:.0} cycles/step × {} steps ⇒ {:.1} end-to-end FPS (paper: 32)",
        hw.syn_per_cycle, hw.clock_mhz, update_cycles as f64, t_present, fps
    );
    println!("(full sweep: `cargo bench --bench bench_table2_mnist`)");
}
