//! Quickstart: the whole FireFly-P pipeline in one minute.
//!
//! 1. Train a plasticity rule offline on a reduced budget (Phase 1).
//! 2. Deploy it: run an online-adaptation episode from zero weights
//!    (Phase 2) on the native backend and — when `make artifacts` has
//!    run — the AOT XLA artifact (the production path).
//! 3. Print the FPGA resource/latency headline numbers.
//!
//! Run: `cargo run --release --example quickstart`

use firefly_p::backend::{NativeBackend, XlaBackend};
use firefly_p::coordinator::adapt_loop::{run_adaptation, AdaptConfig};
use firefly_p::coordinator::offline::{train_rule, TrainConfig};
use firefly_p::env::protocol::{train_grid, TaskFamily};
use firefly_p::es::eval::GenomeKind;
use firefly_p::fpga::power::{Activity, PowerModel};
use firefly_p::fpga::resources::{NetGeometry, ResourceReport};
use firefly_p::fpga::HwConfig;
use firefly_p::runtime::Registry;
use firefly_p::snn::NetworkRule;

fn main() {
    println!("=== FireFly-P quickstart ===\n");

    // ---- Phase 1: offline rule optimization (reduced budget) ----------
    println!("[1/3] Phase 1 — evolving a plasticity rule on cheetah-vel ...");
    let mut cfg = TrainConfig::quick("cheetah-vel", GenomeKind::PlasticityRule);
    cfg.generations = 20;
    cfg.pairs = 12;
    cfg.hidden = 32;
    let result = train_rule(&cfg);
    println!(
        "      fitness: gen0 {:.2} → gen{} {:.2}",
        result.history.first().unwrap().mean_fitness,
        result.history.len() - 1,
        result.history.last().unwrap().mean_fitness
    );

    // ---- Phase 2: online adaptation from zero weights ------------------
    println!("\n[2/3] Phase 2 — online adaptation on a training velocity ...");
    let spec = cfg.spec();
    let net_cfg = spec.snn_config();
    let rule = NetworkRule::from_flat(&net_cfg, &result.genome);
    let task = train_grid(TaskFamily::Velocity)[3].clone();
    let acfg = AdaptConfig {
        env_name: "cheetah-vel".into(),
        seed: 1,
        ..Default::default()
    };

    let mut native = NativeBackend::plastic(net_cfg.clone(), rule.clone());
    let log = run_adaptation(&mut native, &acfg, &task);
    println!("      native backend: episode reward {:.2}", log.total_reward);

    // the AOT/XLA production path needs `make artifacts` and the
    // matching geometry (hidden=128); demonstrate loading when present.
    match Registry::open_default() {
        Ok(_) if net_cfg.n_hidden == 128 => match XlaBackend::plastic("cheetah", &rule) {
            Ok(mut xla) => {
                let log = run_adaptation(&mut xla, &acfg, &task);
                println!("      xla backend:    episode reward {:.2}", log.total_reward);
            }
            Err(e) => println!("      (xla backend unavailable: {e})"),
        },
        Ok(_) => println!("      (xla path skipped: quickstart uses hidden=32, artifacts are 128)"),
        Err(e) => println!("      ({e})"),
    }

    // ---- Hardware headline numbers -------------------------------------
    println!("\n[3/3] FPGA instance (Table I geometry) ...");
    let hw = HwConfig::default();
    let report = ResourceReport::build(&hw, &NetGeometry::paper_control());
    let t = report.total();
    let p = PowerModel::new(report).estimate(&Activity::nominal());
    println!(
        "      {:.1} kLUTs, {} DSPs, {:.1} BRAMs @ {} MHz — {:.3} W",
        t.luts / 1000.0,
        t.dsps as u64,
        t.brams,
        hw.clock_mhz,
        p.total()
    );
    println!("\nDone. Next: examples/adaptive_control.rs for the full EXP-E2E run.");
}
