//! EXP-E2E — the required end-to-end driver, proving all layers compose:
//!
//! Phase 1 (L3 leader + native workers): PEPG evolves the plasticity
//! rule on ant-dir's 8 training directions for a few hundred
//! generations-equivalent of rollouts (budget-reduced here; pass
//! `--full` for the paper-scale run).
//!
//! Phase 2 (L3 + runtime + L2/L1 artifact): the frozen rule θ* is
//! installed into the AOT-compiled XLA step artifact (the HLO lowered
//! from the Pallas kernels) and deployed: the controller starts from
//! **zero weights**, adapts online to a *novel* target direction, and
//! at mid-episode a leg failure is injected — the rule must develop
//! compensatory behaviour. Falls back to the native backend when
//! artifacts aren't built.
//!
//! Output: per-phase reward rates, recovery ratio, CSV of the episode,
//! result lines for EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example adaptive_control [-- --full]`

use firefly_p::backend::{NativeBackend, SnnBackend, XlaBackend};
use firefly_p::coordinator::adapt_loop::{run_adaptation, AdaptConfig};
use firefly_p::coordinator::offline::{train_rule, TrainConfig};
use firefly_p::env::protocol::{eval_grid, TaskFamily};
use firefly_p::env::Perturbation;
use firefly_p::es::eval::{rollout_fitness, EvalSpec, GenomeKind};
use firefly_p::runtime::Registry;
use firefly_p::snn::NetworkRule;
use firefly_p::util::csvio::CsvWriter;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    println!("=== EXP-E2E: Phase 1 → Phase 2 with leg failure (ant-dir) ===\n");

    // ------------------------------------------------ Phase 1 (offline)
    let mut cfg = TrainConfig::quick("ant-dir", GenomeKind::PlasticityRule);
    if full {
        cfg = TrainConfig::paper("ant-dir", GenomeKind::PlasticityRule);
        cfg.hidden = 128; // matches the `ant` AOT artifact geometry
    } else {
        cfg.generations = 40;
        cfg.pairs = 16;
        cfg.hidden = 128; // keep artifact-compatible even in quick mode
        cfg.n_tasks = 4;
    }
    println!(
        "[Phase 1] PEPG: {} generations × {} rollouts/gen on {} training directions",
        cfg.generations,
        2 * cfg.pairs,
        cfg.n_tasks
    );
    let t0 = std::time::Instant::now();
    let result = train_rule(&cfg);
    println!(
        "[Phase 1] done in {:.1}s: pop-mean fitness {:.2} → {:.2}\n",
        t0.elapsed().as_secs_f64(),
        result.history.first().unwrap().mean_fitness,
        result.history.last().unwrap().mean_fitness
    );

    // ---------------------------------------------- Phase 2 (deployment)
    let spec = cfg.spec();
    let net_cfg = spec.snn_config();
    let rule = NetworkRule::from_flat(&net_cfg, &result.genome);

    // Generalization check on novel directions (Fig. 3's protocol).
    let novel = eval_grid(TaskFamily::Direction);
    let eval_spec = EvalSpec {
        tasks: novel[..8].to_vec(),
        ..spec.clone()
    };
    let novel_fit = rollout_fitness(&eval_spec, &result.genome);
    let zero_fit = rollout_fitness(&eval_spec, &vec![0.0; result.genome.len()]);
    println!(
        "[Phase 2] novel-direction fitness: trained rule {novel_fit:.2} vs zero rule {zero_fit:.2}"
    );

    // Deploy through the production path (XLA artifact) when available.
    let mut backend: Box<dyn SnnBackend> = match Registry::open_default() {
        Ok(_) => match XlaBackend::plastic("ant", &rule) {
            Ok(b) => {
                println!("[Phase 2] backend: AOT XLA artifact (ant_step.hlo.txt via PJRT)");
                Box::new(b)
            }
            Err(e) => {
                println!("[Phase 2] backend: native (xla unavailable: {e})");
                Box::new(NativeBackend::plastic(net_cfg.clone(), rule.clone()))
            }
        },
        Err(e) => {
            println!("[Phase 2] backend: native ({e})");
            Box::new(NativeBackend::plastic(net_cfg.clone(), rule.clone()))
        }
    };

    // Online adaptation on a novel direction with a mid-episode leg
    // failure.
    let task = novel[17].clone();
    let acfg = AdaptConfig {
        env_name: "ant-dir".into(),
        perturbation: Some(Perturbation::leg_failure(vec![0])),
        perturb_at: 100,
        seed: 11,
        window: 20,
    };
    println!(
        "[Phase 2] adapting online to novel direction {:.1}° with leg-0 failure at t=100 ...",
        task.value.to_degrees()
    );
    let log = run_adaptation(backend.as_mut(), &acfg, &task);

    let mut csv = CsvWriter::create("results/exp_e2e_episode.csv", &["t", "reward"]).unwrap();
    for (t, r) in log.rewards.iter().enumerate() {
        csv.row_f64(&[t as f64, *r]).unwrap();
    }
    let path = csv.finish().unwrap();

    println!("\n=== EXP-E2E results ===");
    println!("backend                = {}", backend.name());
    println!("total episode reward   = {:.2}", log.total_reward);
    println!("pre-perturbation rate  = {:.3}", log.pre_perturb_rate);
    println!("post-shock rate        = {:.3}", log.shock_rate);
    println!("final rate             = {:.3}", log.final_rate);
    println!("recovery ratio         = {:.3}", log.recovery_ratio());
    println!("episode CSV            = {}", path.display());
}
