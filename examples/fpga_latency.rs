//! FPGA latency walk-through: step the cycle-accurate simulator on the
//! paper's control network and print where the cycles go — prologue,
//! Phase A / Phase B overlap, memory-arbitration stalls — plus the
//! end-to-end µs/step against the paper's 8 µs claim.
//!
//! Run: `cargo run --release --example fpga_latency`

use firefly_p::fpga::power::{Activity, PowerModel};
use firefly_p::fpga::resources::{NetGeometry, ResourceReport};
use firefly_p::fpga::{layout, FpgaSim, HwConfig};
use firefly_p::snn::plasticity::RuleParams;
use firefly_p::snn::SnnConfig;
use firefly_p::util::rng::Pcg64;

fn main() {
    println!("=== FireFly-P cycle-accurate latency walk-through ===\n");
    // The paper's hardware instance: 32-128-8 control network, 16 PEs,
    // 200 MHz (Table I geometry).
    let geo = NetGeometry::paper_control();
    let mut cfg = SnnConfig::control(geo.n_in, geo.n_out);
    cfg.n_hidden = geo.n_hidden;

    let mut rng = Pcg64::new(1, 0);
    let l1 = RuleParams::random(cfg.n_in, cfg.n_hidden, 0.2, &mut rng);
    let l2 = RuleParams::random(cfg.n_hidden, cfg.n_out, 0.2, &mut rng);

    for (label, hw) in [
        ("overlapped dual-engine (paper)", HwConfig::default()),
        ("sequential ablation", HwConfig::sequential()),
    ] {
        let mut sim = FpgaSim::new_plastic(cfg.clone(), l1.clone(), l2.clone(), hw.clone());
        let steps = 200;
        for _ in 0..steps {
            let spikes: Vec<bool> = (0..cfg.n_in).map(|_| rng.bernoulli(0.5)).collect();
            sim.step(&spikes);
        }
        sim.finish();
        let c = &sim.cycles;
        println!("--- {label}");
        println!(
            "    cycles/step {:.0}  ⇒  {:.2} µs/step @ {} MHz  ({:.0} steps/s)",
            sim.steady_state_cycles_per_step(),
            sim.latency_us(),
            hw.clock_mhz,
            sim.fps()
        );
        println!(
            "    prologue {}  phaseA {}  phaseB {}  epilogue {}  total {}",
            c.prologue, c.phase_a, c.phase_b, c.epilogue, c.total
        );
        println!(
            "    engine busy: forward {:.0}%  plasticity {:.0}%   BRAM conflicts: {}",
            100.0 * c.fwd_busy as f64 / c.total as f64,
            100.0 * c.plast_busy as f64 / c.total as f64,
            sim.mem.total_conflicts()
        );
        let act = Activity::from_sim(&sim);
        let report = ResourceReport::build(&hw, &geo);
        let p = PowerModel::new(report).estimate(&act);
        println!("    power at measured activity: {:.3} W\n", p.total());
    }

    println!("paper claims: 8 µs end-to-end, 0.713 W\n");
    let report = ResourceReport::build(&HwConfig::default(), &geo);
    print!("{}", layout::render_floorplan(&report));
}
